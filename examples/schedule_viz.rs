//! Schedule visualization — Figs. 2, 4 and 5 of the paper, regenerated.
//!
//! Prints (a) the adder-tree decomposition and RPO storage analysis for the
//! paper's 1023-input example (Fig. 2b), (b) the cycle-by-cycle control
//! trace of a 4-bit addition (Fig. 4a), the accumulator (Fig. 4c), the
//! sequential comparator (Fig. 5a) and maxpool (Fig. 5b).
//!
//! Run: `cargo run --release --example schedule_viz`

use tulip::pe::{Src, TulipPe, WSrc};
use tulip::scheduler::adder_tree::{threshold_node, AdderTree};
use tulip::scheduler::{ops, storage, Loc, Schedule};

fn src_str(s: Src) -> String {
    match s {
        Src::Zero => "0".into(),
        Src::One => "1".into(),
        Src::Ext(i) => format!("ext{i}"),
        Src::N(k) => format!("N{}", k + 1),
        Src::NInv(k) => format!("!N{}", k + 1),
        Src::NFresh(k) => format!("N{}*", k + 1),
        Src::NFreshInv(k) => format!("!N{}*", k + 1),
        Src::Reg { reg, bit } => format!("R{}[{}]", reg + 1, bit),
        Src::RegInv { reg, bit } => format!("!R{}[{}]", reg + 1, bit),
    }
}

fn trace(title: &str, sched: &Schedule) {
    println!("\n--- {title} ({} cycles) ---", sched.cycles());
    println!("{:>3}  {:<24} {:<40} {}", "cy", "buses", "neurons (a|b|c|d >= T)", "writes / note");
    for (cy, w) in sched.words.iter().enumerate() {
        let mut neurons = String::new();
        for (k, n) in w.neurons.iter().enumerate() {
            if n.gated {
                continue;
            }
            let b = if n.b_en { if n.b_inv { "!b" } else { "b" } } else { "-" };
            let c = if n.c_en { if n.c_inv { "!c" } else { "c" } } else { "-" };
            neurons.push_str(&format!(
                "N{}[{}|{}|{}|{}>={}]{} ",
                k + 1,
                src_str(n.a),
                b,
                c,
                src_str(n.d),
                n.threshold,
                if n.phase == 1 { "'" } else { "" }
            ));
        }
        let writes: Vec<String> = w
            .writes
            .iter()
            .map(|wr| {
                let src = match wr.src {
                    WSrc::N(k) => format!("N{}", k + 1),
                    WSrc::NInv(k) => format!("!N{}", k + 1),
                    WSrc::NOld(k) => format!("N{}(old)", k + 1),
                    WSrc::Ext(i) => format!("ext{i}"),
                    WSrc::Reg { reg, bit } => format!("R{}[{}]", reg + 1, bit),
                    WSrc::Zero => "0".into(),
                    WSrc::One => "1".into(),
                };
                format!("R{}[{}]<={src}", wr.reg + 1, wr.bit)
            })
            .collect();
        println!(
            "{:>3}  b={:<9} c={:<9} {:<40} {}  {}",
            cy,
            src_str(w.bus_b),
            src_str(w.bus_c),
            neurons,
            writes.join(" "),
            w.note.as_deref().unwrap_or("")
        );
    }
}

fn main() {
    // ---- Fig. 2(b): the 1023-input node -------------------------------
    println!("=== Fig. 2(b): 1023-input threshold node, RPO schedule ===");
    let tree = AdderTree::build(1023);
    let leaves = tree.nodes.iter().filter(|n| n.children.is_none()).count();
    println!(
        "decomposition: {leaves} leaf full-adders, {} levels, root sum width {} bits",
        tree.levels(),
        tree.root_width()
    );
    let prog = threshold_node(1023, 512);
    println!(
        "schedule: {} cycles total ({} tree + {} compare)",
        prog.total_cycles(),
        prog.tree_cycles,
        prog.cmp_cycles
    );
    let rep = storage::report(1023);
    println!(
        "storage: exact peak {} bits | paper bound {} bits | physical {} bits",
        rep.exact_peak_bits, rep.paper_bound_bits, rep.physical_bits
    );
    println!("\nstorage scaling (the O(log^2 N) law of §III-B):");
    println!("{:>8} {:>10} {:>12}", "N", "peak bits", "paper bound");
    for n in [48usize, 96, 192, 288, 384, 768, 1023, 2047] {
        let r = storage::report(n);
        println!("{:>8} {:>10} {:>12}", n, r.exact_peak_bits, r.paper_bound_bits);
    }

    // Node numbering of a small tree (the Fig. 2b labels).
    println!("\nRPO node numbering for a 48-input tree (leaf ids in schedule order):");
    let t48 = AdderTree::build(48);
    println!(
        "  {} leaves -> {} internal nodes, {} total cycles",
        t48.nodes.iter().filter(|n| n.children.is_none()).count(),
        t48.nodes.iter().filter(|n| n.children.is_some()).count(),
        t48.sum_cycles()
    );

    // ---- Fig. 4(a): 4-bit addition ------------------------------------
    let add = ops::add(
        Loc::Reg { reg: 0, lsb: 0, width: 4 },
        Loc::Reg { reg: 3, lsb: 0, width: 4 },
        1,
        0,
        ops::SUM_N,
        ops::CARRY_N,
    );
    trace("Fig. 4(a): 4-bit addition x+y (x in R1, y in R4, sum -> R2)", &add);
    // Execute it to show the numbers.
    let mut pe = TulipPe::new();
    pe.regs_mut().poke_field(0, 0, 4, 11);
    pe.regs_mut().poke_field(3, 0, 4, 6);
    add.run_on(&mut pe, &[]);
    println!("    11 + 6 = {} (R2[0..5])", pe.regs().peek_field(1, 0, 5));

    // ---- Fig. 4(c): accumulation ---------------------------------------
    let acc = ops::accumulate(
        Loc::Reg { reg: 1, lsb: 0, width: 5 },
        Loc::Reg { reg: 0, lsb: 0, width: 4 },
        3,
        0,
    );
    trace("Fig. 4(c): accumulate q += p (q alternates R2 <-> R4)", &acc);

    // ---- Fig. 5(a): sequential comparator ------------------------------
    let cmp = ops::compare_gt(
        Loc::Reg { reg: 0, lsb: 0, width: 4 },
        Loc::Reg { reg: 1, lsb: 0, width: 4 },
        ops::CMP_N,
    );
    trace("Fig. 5(a): 4-bit sequential comparator x > y (3-input neuron)", &cmp);

    // ---- Fig. 5(b): maxpool --------------------------------------------
    let pool = ops::maxpool_or(&[0, 1, 2, 3], ops::CMP_N);
    trace("Fig. 5(b): 2x2 maxpool window (single-cycle OR)", &pool);
    let pool9 = ops::maxpool_or(&(0..9).collect::<Vec<_>>(), ops::CMP_N);
    trace("Fig. 5(b) extended: 3x3 overlapping-pool window", &pool9);

    // ---- ReLU (§IV-D) ---------------------------------------------------
    let relu = ops::relu(Loc::Reg { reg: 0, lsb: 0, width: 4 }, 5, 1, 0);
    trace("ReLU: compare then AND-mask ([1,1;2])", &relu);
}

//! AlexNet sweep — the paper's §V-C evaluation regenerated, plus the two
//! design-space sweeps the paper claims but does not plot: PE-count
//! scalability ("the throughput can simply be increased linearly by adding
//! PEs") and off-chip-bandwidth sensitivity (the fetch-bound/compute-bound
//! crossover the Table III refetch economy is about).
//!
//! Run: `cargo run --release --example alexnet_sweep`

use tulip::bnn::alexnet;
use tulip::config::ArchConfig;
use tulip::coordinator::NetworkPerf;
use tulip::metrics;
use tulip::util::bench::print_table;

fn main() {
    let net = alexnet();

    // Per-layer breakdown (the Table III / IV substrate).
    metrics::print_table3(&net);
    for cfg in [ArchConfig::yodann(), ArchConfig::tulip()] {
        let perf = NetworkPerf::model(&net, &cfg);
        let rows: Vec<Vec<String>> = perf
            .layers
            .iter()
            .map(|l| {
                vec![
                    l.name.clone(),
                    if l.binary { "bin" } else { "int" }.into(),
                    l.tiling.p.to_string(),
                    l.tiling.z.to_string(),
                    l.compute_cycles.to_string(),
                    l.fetch_cycles.to_string(),
                    l.total_cycles.to_string(),
                    if l.fetch_cycles > l.compute_cycles { "fetch" } else { "compute" }.into(),
                ]
            })
            .collect();
        print_table(
            &format!("AlexNet per-layer on {}", cfg.kind),
            &["layer", "kind", "P", "Z", "compute", "fetch", "total", "bound"],
            &rows,
        );
    }

    metrics::print_comparison(&net, true);
    metrics::print_comparison(&net, false);

    // ---- Sweep 1: PE count (scalability claim, §I item 1) --------------
    let mut rows = Vec::new();
    for pes in [64usize, 128, 256, 512, 1024] {
        let perf = NetworkPerf::model(&net, &ArchConfig::tulip().with_pes(pes));
        let c = perf.conv_aggregate();
        rows.push(vec![
            pes.to_string(),
            format!("{:.1}", c.gops),
            format!("{:.1}", c.time_ms),
            format!("{:.1}", c.energy_uj),
            format!("{:.2}", c.tops_per_w),
        ]);
    }
    print_table(
        "Sweep: TULIP PE count (conv layers, AlexNet)",
        &["PEs", "GOp/s", "time (ms)", "energy (uJ)", "TOp/s/W"],
        &rows,
    );

    // ---- Sweep 2: off-chip bandwidth (fetch/compute crossover) ---------
    let mut rows = Vec::new();
    for bw in [0.5f64, 1.0, 2.0, 3.05, 6.0, 12.0, 24.0] {
        let t = NetworkPerf::model(&net, &ArchConfig::tulip().with_offchip_bw(bw));
        let y = NetworkPerf::model(&net, &ArchConfig::yodann().with_offchip_bw(bw));
        let (tc, yc) = (t.conv_aggregate(), y.conv_aggregate());
        rows.push(vec![
            format!("{bw}"),
            format!("{:.1}", yc.time_ms),
            format!("{:.1}", tc.time_ms),
            format!("{:.2}", yc.time_ms / tc.time_ms),
            format!("{:.2}", tc.tops_per_w / yc.tops_per_w),
        ]);
    }
    print_table(
        "Sweep: off-chip bandwidth (bits/cycle) — conv layers, AlexNet",
        &["bw", "YodaNN ms", "TULIP ms", "speedup (X)", "eff. gain (X)"],
        &rows,
    );
    println!(
        "\nNote: TULIP's refetch economy (Table III) matters most at low bandwidth —\n\
         the speedup column shrinks as the interface widens and both designs\n\
         become compute-bound."
    );
}

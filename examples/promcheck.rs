//! `promcheck` — validate a Prometheus text exposition.
//!
//! Reads an exposition from a file (or stdin when no path is given), runs
//! it through the in-repo format checker
//! ([`tulip::metrics::check_exposition`]: name/label grammar, sample
//! values, `# TYPE` placement, histogram completeness), and asserts that
//! every `--require PREFIX` matches at least one sample line. Exits
//! non-zero on any violation — CI scrapes `tulip serve --metrics-addr`
//! under load and feeds the body through this binary.
//!
//! ```sh
//! curl -s http://127.0.0.1:9091/metrics | cargo run --example promcheck -- \
//!     --require tulip_serve_admitted_total \
//!     --require 'tulip_serve_latency_us_total_rolling{model="tiny"'
//! ```

use std::io::Read;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut requires: Vec<String> = Vec::new();
    let mut path: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--require" => {
                match argv.get(i + 1) {
                    Some(prefix) => requires.push(prefix.clone()),
                    None => fail("--require needs a series prefix".into()),
                }
                i += 2;
            }
            other if other.starts_with("--") => {
                let usage = "usage: promcheck [PATH] [--require PREFIX]...";
                fail(format!("unknown flag '{other}' ({usage})"));
            }
            other => {
                if path.replace(other.to_string()).is_some() {
                    fail("at most one input path (omit it to read stdin)".into());
                }
                i += 1;
            }
        }
    }

    let text = match &path {
        Some(p) => match std::fs::read_to_string(p) {
            Ok(t) => t,
            Err(e) => fail(format!("reading {p}: {e}")),
        },
        None => {
            let mut t = String::new();
            if let Err(e) = std::io::stdin().read_to_string(&mut t) {
                fail(format!("reading stdin: {e}"));
            }
            t
        }
    };

    let stats = match tulip::metrics::check_exposition(&text) {
        Ok(s) => s,
        Err(e) => fail(format!("invalid exposition: {e:#}")),
    };
    let mut missing = 0;
    for prefix in &requires {
        if stats.has_series(prefix) {
            println!("ok: series matching '{prefix}'");
        } else {
            eprintln!("MISSING: no series matching '{prefix}'");
            missing += 1;
        }
    }
    println!(
        "exposition valid: {} families, {} samples ({} of {} required series present)",
        stats.families,
        stats.samples,
        requires.len() - missing,
        requires.len()
    );
    if missing > 0 {
        std::process::exit(1);
    }
}

fn fail(msg: String) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(1);
}

//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Proves all layers of the stack compose on a real (small) workload:
//!
//!   1. `make artifacts` compiled the JAX/Pallas golden model to HLO text;
//!   2. the rust runtime loads it on the PJRT CPU client (python is NOT on
//!      this path);
//!   3. a batch of synthetic CIFAR-like images is classified twice — by the
//!      golden model and by the **bit-true TULIP-PE simulation** (every
//!      activation computed through real control words on the 4-neuron
//!      threshold-logic PEs);
//!   4. classifications must agree image-for-image; throughput, simulated
//!      latency and energy are reported from the calibrated model.
//!
//! Run: `make artifacts && cargo run --release --example e2e_inference`

use std::time::Instant;
use tulip::arch::unit::PeArray;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{reference, tiny_bnn};
use tulip::energy::{calib, Activity, EnergyModel};
use tulip::runtime::{literal_bits, literal_i32, Runtime};
use tulip::scheduler::seqgen::SequenceGenerator;
use tulip::sim::cycle;

fn weight_literal(w: &BinWeights) -> xla::Literal {
    let data: Vec<i32> = w.data.iter().map(|&v| v as i32).collect();
    literal_i32(&data, &[w.z2, w.fanin]).unwrap()
}

fn threshold_literal(w: &BinWeights) -> xla::Literal {
    let t: Vec<i32> = w.thresholds.iter().map(|&v| v as i32).collect();
    literal_i32(&t, &[w.z2]).unwrap()
}

fn argmax(scores: &[i32]) -> usize {
    scores.iter().enumerate().max_by_key(|(_, &s)| s).map(|(i, _)| i).unwrap()
}

fn main() {
    const BATCH: usize = 32;
    let rt = Runtime::new("artifacts").expect("PJRT client");
    println!("PJRT platform: {}", rt.platform());
    let model = match rt.load("tiny_bnn") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e:#}\nRun `make artifacts` first.");
            std::process::exit(1);
        }
    };

    // Network + frozen synthetic weights (batch-norm thresholds folded).
    let net = tiny_bnn(16, 8, 4);
    let weights: Vec<BinWeights> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 1000 + i as u64))
        .collect();
    println!(
        "network: {} ({} layers, {:.2} MOp/inference)",
        net.name,
        net.layers.len(),
        net.total_mops()
    );

    // ---- Path A: JAX golden model via PJRT (the serving path) ----------
    let t0 = Instant::now();
    let mut golden_classes = Vec::with_capacity(BATCH);
    for img in 0..BATCH {
        let input = BitTensor::random(16, 16, 8, img as u64);
        let scores = model
            .run_i32(&[
                literal_bits(&input.data, &[16, 16, 8]).unwrap(),
                weight_literal(&weights[0]),
                threshold_literal(&weights[0]),
                weight_literal(&weights[1]),
                threshold_literal(&weights[1]),
                weight_literal(&weights[2]),
            ])
            .unwrap();
        golden_classes.push(argmax(&scores));
    }
    let golden_dt = t0.elapsed();

    // ---- Path B: bit-true TULIP-PE simulation --------------------------
    let mut array = PeArray::paper(); // 32 units × 8 PEs = 256 PEs
    let mut sg = SequenceGenerator::new();
    let mut sim_classes = Vec::with_capacity(BATCH);
    let mut sim_cycles = 0u64;
    let t1 = Instant::now();
    for img in 0..BATCH {
        let input = BitTensor::random(16, 16, 8, img as u64);
        let c1 = cycle::conv_bin_cycle(&mut array, &mut sg, &input, &net.layers[0], &weights[0]);
        let p1 = cycle::maxpool_cycle(&mut array, &mut sg, &c1.output, 2, 2);
        let c2 =
            cycle::conv_bin_cycle(&mut array, &mut sg, &p1.output, &net.layers[1], &weights[1]);
        let p2 = cycle::maxpool_cycle(&mut array, &mut sg, &c2.output, 2, 2);
        let (_, scores, fc_cy) = cycle::fc_bin_cycle(
            &mut array,
            &mut sg,
            &p2.output.flatten(),
            &net.layers[2],
            &weights[2],
        );
        sim_cycles += c1.cycles + p1.cycles + c2.cycles + p2.cycles + fc_cy;
        sim_classes.push(argmax(&scores.iter().map(|&s| s as i32).collect::<Vec<_>>()));
    }
    let sim_dt = t1.elapsed();

    // ---- Path C: functional reference (sanity triangle) ----------------
    let mut ref_classes = Vec::with_capacity(BATCH);
    for img in 0..BATCH {
        let input = BitTensor::random(16, 16, 8, img as u64);
        let scores = reference::forward_scores(&net, &input, &weights);
        ref_classes.push(argmax(&scores.iter().map(|&s| s as i32).collect::<Vec<_>>()));
    }

    assert_eq!(golden_classes, sim_classes, "golden vs bit-true PE classifications");
    assert_eq!(golden_classes, ref_classes, "golden vs functional classifications");
    println!(
        "\n{} images classified — golden (PJRT), bit-true PE sim and functional\n\
         reference agree image-for-image OK  (class histogram: {:?})",
        BATCH,
        (0..4).map(|c| golden_classes.iter().filter(|&&x| x == c).count()).collect::<Vec<_>>()
    );

    // ---- Reported metrics ----------------------------------------------
    let stats = array.stats();
    let m = EnergyModel::default();
    let act = Activity {
        pe_neuron_evals: stats.neuron_evals,
        pe_reg_accesses: stats.reg_reads + stats.reg_writes,
        pe_gated_neuron_cycles: stats.gated_neuron_cycles,
        total_cycles: sim_cycles,
        ..Default::default()
    };
    let e = m.energy(&act);
    println!("\n-- serving path (PJRT golden) --");
    println!(
        "  host latency {:.2} ms/image, throughput {:.1} images/s",
        golden_dt.as_secs_f64() * 1e3 / BATCH as f64,
        BATCH as f64 / golden_dt.as_secs_f64()
    );
    println!("-- simulated TULIP chip (bit-true, 256 PEs) --");
    println!(
        "  {} cycles/image = {:.1} us/image at the {} ns clock ({:.0} images/s on-chip)",
        sim_cycles / BATCH as u64,
        m.seconds(sim_cycles / BATCH as u64) * 1e6,
        calib::CLOCK_NS,
        1.0 / m.seconds(sim_cycles / BATCH as u64)
    );
    println!(
        "  PE energy {:.2} nJ/image ({} neuron evals total)",
        e.total_pj() * 1e-3 / BATCH as f64,
        stats.neuron_evals
    );
    println!("  simulator wall time {:.2} s for {} images", sim_dt.as_secs_f64(), BATCH);
}

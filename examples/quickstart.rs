//! Quickstart: the public API in ~60 lines.
//!
//! Builds a binary conv layer, runs it **bit-true** on a simulated
//! TULIP-PE array (every output bit produced by real control words on the
//! 4-neuron threshold-logic PEs), checks it against the functional
//! reference, and prices the run with the calibrated energy model.
//!
//! Run: `cargo run --release --example quickstart`

use tulip::arch::unit::PeArray;
use tulip::bnn::layer::LayerKind;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{reference, Layer};
use tulip::energy::{calib, Activity, EnergyModel};
use tulip::scheduler::adder_tree::threshold_node;
use tulip::scheduler::seqgen::SequenceGenerator;
use tulip::sim::cycle;

fn main() {
    // 1. A binary conv layer: 16×16×32 input, 3×3 kernel, 64 OFM channels —
    //    each output neuron is the 288-input node of the paper's Table II.
    let layer = Layer::conv("demo", LayerKind::ConvBin, (16, 16, 32), 3, 1, 1, 64, None);
    println!("layer: {} (fan-in {} per output neuron)", layer.name, layer.fanin());

    // 2. The schedule a TULIP-PE runs per output: adder tree in reverse
    //    post-order + sequential threshold comparison (Fig. 2b).
    let node = threshold_node(layer.fanin(), (layer.fanin() / 2) as i64);
    println!(
        "per-node schedule: {} cycles ({} tree + {} compare), peak storage {} of 64 bits",
        node.total_cycles(),
        node.tree_cycles,
        node.cmp_cycles,
        node.peak_storage_bits
    );

    // 3. Bit-true execution on a PE array (8 PEs here; the paper's chip has
    //    256) against synthetic data.
    let input = BitTensor::random(16, 16, 32, 42);
    let weights = BinWeights::random(64, layer.fanin(), 7);
    let mut array = PeArray::new(2, 4);
    let mut sg = SequenceGenerator::new();
    let result = cycle::conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);

    // 4. Verify against the functional reference — bit-for-bit.
    let expect = reference::conv_bin(&input, &layer, &weights);
    assert_eq!(result.output, expect, "bit-true output must match the reference");
    println!("bit-true output matches the functional reference OK");

    // 5. Price the activity with the calibrated energy model.
    let m = EnergyModel::default();
    let act = Activity {
        pe_neuron_evals: result.stats.neuron_evals,
        pe_reg_accesses: result.stats.reg_reads + result.stats.reg_writes,
        pe_gated_neuron_cycles: result.stats.gated_neuron_cycles,
        total_cycles: result.cycles,
        ..Default::default()
    };
    let e = m.energy(&act);
    println!(
        "simulated {} wall cycles = {:.1} us at the paper's {} ns clock",
        result.cycles,
        m.seconds(result.cycles) * 1e6,
        calib::CLOCK_NS
    );
    println!(
        "energy: {:.2} nJ ({} neuron evals, {} register accesses)",
        e.total_pj() * 1e-3,
        result.stats.neuron_evals,
        result.stats.reg_reads + result.stats.reg_writes
    );
    println!("\nnext: examples/schedule_viz, examples/alexnet_sweep, examples/e2e_inference");
}

//! Profiling driver for the bit-true hot path (EXPERIMENTS.md §Perf):
//!
//!   cargo build --release --example profconv
//!   perf record -g target/release/examples/profconv
//!   perf report --stdio --no-children --no-inline
use tulip::arch::unit::PeArray;
use tulip::bnn::layer::LayerKind;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::Layer;
use tulip::scheduler::seqgen::SequenceGenerator;
use tulip::sim::cycle;

fn main() {
    let layer = Layer::conv("b", LayerKind::ConvBin, (8, 8, 16), 3, 1, 1, 8, None);
    let input = BitTensor::random(8, 8, 16, 5);
    let weights = BinWeights::random(8, layer.fanin(), 6);
    let mut total = 0u64;
    for _ in 0..200 {
        let mut array = PeArray::new(2, 4);
        let mut sg = SequenceGenerator::new();
        total += cycle::conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights).cycles;
    }
    println!("{total}");
}

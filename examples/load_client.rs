//! `load_client` — open-loop traffic generator for `tulip serve`.
//!
//! Drives configurable open-loop load (arrival rate, burst factor,
//! deadline mix) over N connections against a running server, verifies
//! every `ok` response bit-for-bit against a local `BatchExecutor`, and
//! prints p50/p99 latency plus realized batch occupancy. Exits non-zero on
//! any error, any rejection (unless `--allow-reject`), a shed when
//! `--deadline-frac` is 0, or a p99 over `--assert-p99-us`.
//!
//! Multi-model knobs: every request carries `"model": NAME` (from
//! `--model`), so one client exercises exactly one lane of a multi-model
//! server. `--model-file PATH` builds the local verification oracle from a
//! `tulip.model/v1` file instead of the built-in demo models;
//! `--load-model` first hot-loads that document onto the server under
//! NAME (wire `{"op": "load_model"}`); `--unload` retires the lane after
//! traffic and fails unless the server reports `"accounted": true`.
//!
//! Telemetry knobs: `--trace` pulls the server's flight recorder after
//! traffic (wire `{"op": "trace_dump"}`) and checks that every `ok`
//! response has a complete admit→respond flight chain (strict only while
//! the ring reports zero drops); `--csv PATH` writes one
//! `id,status,queue_us,batch_us,total_us,batch_n` row per response.
//!
//! ```sh
//! cargo run --release --example load_client -- \
//!     --addr 127.0.0.1:7070 --model tiny --requests 200 --rate 2000 \
//!     --conns 4 --deadline-frac 0.25 --deadline-ms 1 --drain
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::Model;
use tulip::coordinator::BatchExecutor;
use tulip::metrics::flight::{self, FlightStage};
use tulip::serve::protocol::{json_str, parse_json, Json};
use tulip::serve::{pack_bits, ServeResponse, Status};

#[derive(Clone)]
struct Args {
    addr: String,
    model: String,
    model_file: Option<String>,
    load_model: bool,
    unload: bool,
    requests: usize,
    rate: f64,
    burst: usize,
    conns: usize,
    deadline_frac: f64,
    deadline_ms: u64,
    drain: bool,
    allow_reject: bool,
    assert_p99_us: Option<u64>,
    verify: bool,
    trace: bool,
    csv: Option<String>,
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    Args {
        addr: flag_value(&argv, "--addr").unwrap_or_else(|| "127.0.0.1:7070".into()),
        model: flag_value(&argv, "--model").unwrap_or_else(|| "tiny".into()),
        model_file: flag_value(&argv, "--model-file"),
        load_model: argv.iter().any(|a| a == "--load-model"),
        unload: argv.iter().any(|a| a == "--unload"),
        requests: flag_value(&argv, "--requests").and_then(|v| v.parse().ok()).unwrap_or(200),
        rate: flag_value(&argv, "--rate").and_then(|v| v.parse().ok()).unwrap_or(2000.0),
        burst: flag_value(&argv, "--burst").and_then(|v| v.parse().ok()).unwrap_or(1).max(1),
        conns: flag_value(&argv, "--conns").and_then(|v| v.parse().ok()).unwrap_or(4).max(1),
        deadline_frac: flag_value(&argv, "--deadline-frac")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.0),
        deadline_ms: flag_value(&argv, "--deadline-ms").and_then(|v| v.parse().ok()).unwrap_or(1),
        drain: argv.iter().any(|a| a == "--drain"),
        allow_reject: argv.iter().any(|a| a == "--allow-reject"),
        assert_p99_us: flag_value(&argv, "--assert-p99-us").and_then(|v| v.parse().ok()),
        verify: !argv.iter().any(|a| a == "--no-verify"),
        trace: argv.iter().any(|a| a == "--trace"),
        csv: flag_value(&argv, "--csv"),
    }
}

/// Deterministic image for request `id` — the server never sees the seed,
/// only the packed bits, so bit-identity checks are end-to-end.
fn image_for(id: u64, h: usize, w: usize, c: usize) -> BitTensor {
    BitTensor::random(h, w, c, 5000 + id)
}

/// Pull the server's flight recorder as a parsed `tulip.trace/v1` dump.
fn fetch_trace(addr: &str) -> anyhow::Result<tulip::metrics::FlightDump> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(b"{\"op\": \"trace_dump\"}\n")?;
    s.flush()?;
    let mut reply = String::new();
    BufReader::new(s).read_line(&mut reply)?;
    tulip::metrics::FlightDump::parse(reply.trim())
}

/// Send one control line and return the parsed reply object.
fn control_op(addr: &str, line: &str) -> anyhow::Result<Json> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")?;
    s.flush()?;
    let mut reply = String::new();
    BufReader::new(s).read_line(&mut reply)?;
    parse_json(reply.trim())
}

/// One connection's worth of open-loop traffic: send this connection's
/// request ids at the configured pace while a reader thread collects
/// responses; returns them once one response per request has arrived.
fn drive_connection(
    args: &Args,
    ids: Vec<u64>,
    input: (usize, usize, usize),
) -> anyhow::Result<Vec<ServeResponse>> {
    let (h, w, c) = input;
    let stream = TcpStream::connect(&args.addr)?;
    let expected = ids.len();
    let reader = {
        let stream = stream.try_clone()?;
        std::thread::spawn(move || -> anyhow::Result<Vec<ServeResponse>> {
            let mut responses = Vec::with_capacity(expected);
            for line in BufReader::new(stream).lines() {
                responses.push(ServeResponse::parse(&line?)?);
                if responses.len() == expected {
                    break;
                }
            }
            Ok(responses)
        })
    };
    // Open-loop pacing: the fleet sends `rate` req/s overall, so each of
    // the `conns` connections sends every conns/rate seconds; a burst of B
    // sends B back-to-back and then sleeps B intervals.
    let interval = Duration::from_secs_f64(args.conns as f64 / args.rate.max(1.0));
    let mut sender = stream;
    let deadline_cut = (args.deadline_frac * args.requests as f64) as u64;
    let model = json_str(&args.model);
    for (k, &id) in ids.iter().enumerate() {
        let image = image_for(id, h, w, c);
        let deadline = if id < deadline_cut {
            format!(", \"deadline_ms\": {}", args.deadline_ms)
        } else {
            String::new()
        };
        let line = format!(
            "{{\"id\": {id}, \"model\": {model}, \"h\": {h}, \"w\": {w}, \"c\": {c}, \
             \"bits\": \"{}\"{deadline}}}\n",
            pack_bits(&image.data)
        );
        sender.write_all(line.as_bytes())?;
        if (k + 1) % args.burst == 0 {
            sender.flush()?;
            std::thread::sleep(interval * args.burst as u32);
        }
    }
    sender.flush()?;
    reader.join().expect("reader thread panicked")
}

/// Exact percentile over the collected per-request samples.
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

fn main() -> anyhow::Result<()> {
    let args = parse_args();
    let model = match &args.model_file {
        Some(path) => Model::load(path)?,
        None => Model::demo(&args.model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {} (pass --model-file?)", args.model))?,
    };
    let input = model.input_dims();
    let oracle = if args.verify { Some(Arc::new(BatchExecutor::for_model(&model)?)) } else { None };

    if args.load_model {
        let line = format!(
            "{{\"op\": \"load_model\", \"name\": {}, \"model\": {}}}",
            json_str(&args.model),
            model.to_json()
        );
        let reply = control_op(&args.addr, &line)?;
        if reply.get("ok") != Some(&Json::Bool(true)) {
            anyhow::bail!(
                "load_model '{}' refused: {}",
                args.model,
                reply.get("error").and_then(Json::as_str).unwrap_or("?")
            );
        }
        println!("hot-loaded model '{}' onto {}", args.model, args.addr);
    }

    println!(
        "load_client: {} requests @ {} req/s (burst {}) over {} conns to {} [model {}]",
        args.requests, args.rate, args.burst, args.conns, args.addr, args.model
    );
    let t0 = Instant::now();
    let mut lanes: Vec<Vec<u64>> = vec![Vec::new(); args.conns];
    for id in 0..args.requests as u64 {
        lanes[id as usize % args.conns].push(id);
    }
    let workers: Vec<_> = lanes
        .into_iter()
        .map(|ids| {
            let args = args.clone();
            std::thread::spawn(move || drive_connection(&args, ids, input))
        })
        .collect();
    let mut responses = Vec::with_capacity(args.requests);
    for w in workers {
        responses.extend(w.join().expect("connection thread panicked")?);
    }
    let wall = t0.elapsed();

    // Tally outcomes and verify ok responses against the local oracle.
    let (mut ok, mut shed, mut rejected, mut errors, mut mismatches) = (0u64, 0u64, 0u64, 0u64, 0);
    let mut total_us: Vec<u64> = Vec::new();
    let mut queue_us: Vec<u64> = Vec::new();
    let mut occupancy: Vec<u64> = Vec::new();
    for r in &responses {
        match r.status {
            Status::Ok => {
                ok += 1;
                total_us.push(r.total_us);
                queue_us.push(r.queue_us);
                occupancy.push(r.batch_n as u64);
                if let Some(exec) = &oracle {
                    let (h, w, c) = input;
                    let direct = exec.run_one(0, &image_for(r.id, h, w, c))?;
                    if r.scores != direct.scores || r.class != Some(direct.class) {
                        mismatches += 1;
                        eprintln!(
                            "MISMATCH id {}: {:?} vs local {:?}",
                            r.id,
                            r.scores,
                            direct.scores
                        );
                    }
                }
            }
            Status::Shed => shed += 1,
            Status::Rejected => rejected += 1,
            Status::Error => {
                errors += 1;
                eprintln!("ERROR id {}: {}", r.id, r.error.as_deref().unwrap_or("?"));
            }
        }
    }
    total_us.sort_unstable();
    queue_us.sort_unstable();
    let mean_occ = if occupancy.is_empty() {
        0.0
    } else {
        occupancy.iter().sum::<u64>() as f64 / occupancy.len() as f64
    };

    println!(
        "{} responses in {:.1} ms: {} ok / {} shed / {} rejected / {} errors ({} verify mismatches)",
        responses.len(),
        wall.as_secs_f64() * 1e3,
        ok,
        shed,
        rejected,
        errors,
        mismatches
    );
    println!(
        "latency total p50 {} us / p99 {} us (queue p50 {} us / p99 {} us)",
        percentile(&total_us, 0.50),
        percentile(&total_us, 0.99),
        percentile(&queue_us, 0.50),
        percentile(&queue_us, 0.99)
    );
    println!(
        "occupancy mean {:.1} images/batch (max {})",
        mean_occ,
        occupancy.iter().max().copied().unwrap_or(0)
    );

    let mut failed = false;
    if let Some(path) = &args.csv {
        let mut by_id: Vec<&ServeResponse> = responses.iter().collect();
        by_id.sort_by_key(|r| r.id);
        let mut csv = String::from("id,status,queue_us,batch_us,total_us,batch_n\n");
        for r in by_id {
            csv.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.id,
                r.status.name(),
                r.queue_us,
                r.batch_us,
                r.total_us,
                r.batch_n
            ));
        }
        std::fs::write(path, csv)?;
        println!("per-request CSV ({} rows) written to {path}", responses.len());
    }

    if args.trace {
        // The batcher records a request's Respond event just after handing
        // the reply to the connection writer, so a dump taken the instant
        // the last reply arrives can miss it — let the recorder settle.
        std::thread::sleep(Duration::from_millis(50));
        let dump = fetch_trace(&args.addr)?;
        let lane = flight::lane_id(&args.model);
        let (mut complete, mut incomplete) = (0u64, 0u64);
        for r in &responses {
            if r.status != Status::Ok {
                continue;
            }
            let stages: Vec<FlightStage> = dump
                .events
                .iter()
                .filter(|e| e.request == r.id && e.lane == lane)
                .map(|e| e.stage)
                .collect();
            if stages.contains(&FlightStage::Admit) && stages.contains(&FlightStage::Respond) {
                complete += 1;
            } else {
                incomplete += 1;
            }
        }
        println!(
            "trace: {} events ({} dropped), {complete}/{} ok requests with complete \
             admit->respond chains",
            dump.events.len(),
            dump.dropped,
            complete + incomplete
        );
        // The ring overwrites oldest-first, so chains are only guaranteed
        // intact while nothing has been dropped.
        if incomplete > 0 && dump.dropped == 0 {
            eprintln!("FAIL: {incomplete} ok requests missing admit/respond flight events");
            failed = true;
        }
    }

    if args.unload {
        let line = format!("{{\"op\": \"unload_model\", \"name\": {}}}", json_str(&args.model));
        let reply = control_op(&args.addr, &line)?;
        let accounted = reply.get("accounted") == Some(&Json::Bool(true));
        if reply.get("ok") != Some(&Json::Bool(true)) || !accounted {
            eprintln!("FAIL: unload '{}' not cleanly accounted: {reply:?}", args.model);
            failed = true;
        } else {
            println!(
                "unloaded model '{}' — accounted, {} completed",
                args.model,
                reply.get("completed").and_then(Json::as_u64).unwrap_or(0)
            );
        }
    }

    if args.drain {
        let reply = control_op(&args.addr, "{\"op\": \"drain\"}")?;
        println!("drain ack: {reply:?}");
    }

    if responses.len() != args.requests {
        eprintln!("FAIL: {} responses for {} requests", responses.len(), args.requests);
        failed = true;
    }
    if errors > 0 || mismatches > 0 {
        eprintln!("FAIL: {errors} errors, {mismatches} mismatches");
        failed = true;
    }
    if rejected > 0 && !args.allow_reject {
        eprintln!("FAIL: {rejected} rejections (pass --allow-reject to tolerate)");
        failed = true;
    }
    if shed > 0 && args.deadline_frac == 0.0 {
        eprintln!("FAIL: {shed} sheds with no deadlines requested");
        failed = true;
    }
    if let Some(budget) = args.assert_p99_us {
        let p99 = percentile(&total_us, 0.99);
        if p99 > budget {
            eprintln!("FAIL: p99 {p99} us exceeds budget {budget} us");
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}

//! Batched serving demo — the TULIP simulator as a tiny inference service.
//!
//! Builds a frozen TinyBNN, then serves a 32-image batch through the
//! rayon-parallel bit-true engine: every activation of every image is
//! computed through real control words on simulated 4-neuron TULIP-PEs,
//! with all worker threads sharing one program cache (the simulator
//! equivalent of the paper's single broadcast sequence generator, §IV-E).
//!
//! Demonstrates the determinism guarantee (batching/threading never
//! changes results), then reports everything else through the
//! observability layer: a per-layer/per-PE `PerfReport` built from the
//! batch result, optionally exported as JSON with `--perf-out <path>`.
//!
//! Run: `cargo run --release --example batch_serve [-- --perf-out perf.json]`

use tulip::bnn::tensor::BitTensor;
use tulip::bnn::Model;
use tulip::config::ArchConfig;
use tulip::coordinator::{BatchExecutor, BatchPerf, BatchRequest, PerfReport};
use tulip::metrics::MetricsRegistry;

fn perf_out_arg() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--perf-out" => return args.next(),
            _ if a.starts_with("--perf-out=") => {
                return Some(a["--perf-out=".len()..].to_string())
            }
            _ => {}
        }
    }
    None
}

fn main() {
    const BATCH: u64 = 32;
    // The built-in "tiny" demo model: tiny_bnn(16, 8, 4) with frozen
    // deterministic weights.
    let model = Model::demo("tiny").expect("built-in demo model");
    let net = model.network().clone();
    println!(
        "serving {} ({} layers, {:.2} MOp/inference)",
        net.name,
        net.layers.len(),
        net.total_mops()
    );

    let parallel = BatchExecutor::for_model(&model).unwrap();
    let serial = BatchExecutor::for_model(&model).unwrap().with_threads(1);
    let req = BatchRequest::new((0..BATCH).map(|i| BitTensor::random(16, 16, 8, i)).collect());

    // Serve the batch on all cores, then re-serve it single-threaded and
    // hold the engine to its determinism guarantee.
    let fast = parallel.run(&req).unwrap();
    let slow = serial.run(&req).unwrap();
    for (a, b) in fast.images.iter().zip(&slow.images) {
        assert_eq!(a.scores, b.scores, "batching/threading must not change results");
    }
    println!(
        "{} images classified; parallel == serial bit-for-bit OK (class histogram: {:?})",
        req.len(),
        (0..4).map(|c| fast.classes().iter().filter(|&&x| x == c).count()).collect::<Vec<_>>()
    );
    println!(
        "parallel vs serial wall clock: {:.2}X speedup",
        fast.images_per_sec() / slow.images_per_sec()
    );

    // --- Serving metrics: one report instead of ad-hoc accounting --------
    let report = PerfReport::from_batch(&parallel, &fast)
        .with_metrics(MetricsRegistry::global().snapshot());
    report.print_summary();

    if let Some(path) = perf_out_arg() {
        report.write_json(&path).unwrap();
        println!("\nperf report written to {path}");
    }

    // --- Analytic cross-check -------------------------------------------
    let bp = BatchPerf::model(&net, &ArchConfig::tulip().with_pes(8), req.len());
    println!("\n-- analytic batch model (8 PEs, same batch) --");
    println!(
        "  {} total cycles for the batch ({} per image), {:.0} simulated images/s",
        bp.total_cycles(),
        bp.total_cycles() / BATCH,
        bp.images_per_sec()
    );
    println!("\nsee ROADMAP.md + README.md for the batch API; tests/batch.rs pins the guarantees");
}

//! Batched serving demo — the TULIP simulator as a tiny inference service.
//!
//! Builds a frozen TinyBNN, then serves a 32-image batch through the
//! rayon-parallel bit-true engine: every activation of every image is
//! computed through real control words on simulated 4-neuron TULIP-PEs,
//! with all worker threads sharing one program cache (the simulator
//! equivalent of the paper's single broadcast sequence generator, §IV-E).
//!
//! Demonstrates the determinism guarantee (batching/threading never
//! changes results), the exact energy accounting, and the analytic batch
//! model agreeing with the bit-true cycle counts.
//!
//! Run: `cargo run --release --example batch_serve`

use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::tiny_bnn;
use tulip::config::ArchConfig;
use tulip::coordinator::{BatchExecutor, BatchPerf, BatchRequest};

fn main() {
    const BATCH: u64 = 32;
    let net = tiny_bnn(16, 8, 4);
    let weights: Vec<BinWeights> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 1000 + i as u64))
        .collect();
    println!(
        "serving {} ({} layers, {:.2} MOp/inference)",
        net.name,
        net.layers.len(),
        net.total_mops()
    );

    let parallel = BatchExecutor::new(net.clone(), weights.clone()).unwrap();
    let serial = BatchExecutor::new(net.clone(), weights).unwrap().with_threads(1);
    let req = BatchRequest::new((0..BATCH).map(|i| BitTensor::random(16, 16, 8, i)).collect());

    // Serve the batch on all cores, then re-serve it single-threaded and
    // hold the engine to its determinism guarantee.
    let fast = parallel.run(&req).unwrap();
    let slow = serial.run(&req).unwrap();
    for (a, b) in fast.images.iter().zip(&slow.images) {
        assert_eq!(a.scores, b.scores, "batching/threading must not change results");
    }
    println!(
        "{} images classified; parallel == serial bit-for-bit OK (class histogram: {:?})",
        req.len(),
        (0..4).map(|c| fast.classes().iter().filter(|&&x| x == c).count()).collect::<Vec<_>>()
    );

    // --- Serving metrics -------------------------------------------------
    println!("\n-- host (simulator) throughput --");
    println!(
        "  parallel: {:>8.2} images/s   ({:.1} ms for the batch)",
        fast.images_per_sec(),
        fast.wall.as_secs_f64() * 1e3
    );
    println!(
        "  serial:   {:>8.2} images/s   ({:.1} ms for the batch)  -> {:.2}X speedup",
        slow.images_per_sec(),
        slow.wall.as_secs_f64() * 1e3,
        fast.images_per_sec() / slow.images_per_sec()
    );

    println!("\n-- simulated TULIP chip (bit-true) --");
    println!(
        "  {} cycles/image = {:.1} us/image on-chip, {:.2} nJ/image",
        fast.cycles / BATCH,
        fast.simulated_us_per_image(),
        fast.energy().total_pj() * 1e-3 / BATCH as f64
    );

    // --- The schedule economy behind the throughput ----------------------
    let (hits, misses) = parallel.cache_handle().stats();
    println!("\n-- shared program cache --");
    println!(
        "  {misses} programs planned once, {hits} broadcast hits \
         ({:.1} hits per miss)",
        hits as f64 / misses.max(1) as f64
    );

    // --- Analytic cross-check -------------------------------------------
    let bp = BatchPerf::model(&net, &ArchConfig::tulip().with_pes(8), req.len());
    println!("\n-- analytic batch model (8 PEs, same batch) --");
    println!(
        "  {} total cycles for the batch ({} per image), {:.0} simulated images/s",
        bp.total_cycles(),
        bp.total_cycles() / BATCH,
        bp.images_per_sec()
    );
    println!("\nsee ROADMAP.md + README.md for the batch API; tests/batch.rs pins the guarantees");
}

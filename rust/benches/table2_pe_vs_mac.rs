//! Table II regeneration: the fully reconfigurable YodaNN MAC vs one
//! TULIP-PE on the 288-input neuron (3×3 kernel × 32 IFMs), plus bit-true
//! execution benchmarks of both unit models.
//!
//! Paper row anchors: MAC 3.54e4 µm² / 7.17 mW / 17 cy / 39 ns;
//! TULIP-PE 1.53e3 µm² / 0.12 mW / 441 cy / 1014 ns; PDP advantage 2.27×.
//!
//! Run: `cargo bench --bench table2_pe_vs_mac`

use tulip::baseline::MacUnit;
use tulip::bnn::tensor::BitTensor;
use tulip::metrics;
use tulip::pe::TulipPe;
use tulip::scheduler::seqgen::{OpDesc, SequenceGenerator};
use tulip::util::bench::bench;

fn main() {
    let t2 = metrics::print_table2();
    println!(
        "\npaper: 23.18X area, 59.75X power, 0.038X cycles (17 vs 441), PDP 2.27X\n\
         ours : {:.2}X area, {:.1}X power, {:.3}X cycles ({} vs {}), PDP {:.2}X\n\
         (cycle delta vs the paper's 441 and the Table II/IV power-calibration\n\
          tension are quantified in EXPERIMENTS.md §Table II)",
        t2.mac_area_um2 / t2.pe_area_um2,
        t2.mac_power_mw / t2.pe_power_mw,
        t2.mac_cycles as f64 / t2.pe_cycles as f64,
        t2.mac_cycles,
        t2.pe_cycles,
        t2.pdp_ratio()
    );

    // Bit-true PE node execution rate (simulator hot path).
    let mut sg = SequenceGenerator::new();
    let prog = sg.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 });
    let products = BitTensor::random(1, 1, 288, 3).data;
    bench("bit-true 288-input node on a TULIP-PE", 7, || {
        let mut pe = TulipPe::new();
        prog.schedule.run_on(&mut pe, &products);
        pe.neuron_out(prog.out_neuron.unwrap())
    });

    // MAC functional model.
    let mac = MacUnit::yodann();
    let inputs: Vec<i32> = products.iter().map(|&b| if b { 1 } else { -1 }).collect();
    let weights: Vec<i8> = (0..288).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
    bench("MAC 288-input weighted sum (functional)", 7, || {
        mac.weighted_sum(&inputs, &weights)
    });
}

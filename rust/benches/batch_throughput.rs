//! Batched-inference throughput: images/sec by batch size and worker
//! count, against the single-image serial baseline — with a bit-identical
//! determinism check (batching and threading never change results).
//!
//! Acceptance shape: on a multi-core host the batched multi-thread
//! throughput should reach ≥ 3× the single-image serial throughput; the
//! final line prints the measured ratio.
//!
//! Run: `cargo bench --bench batch_throughput`
//!
//! Emits `BENCH_batch_throughput.json` (schema
//! `tulip.bench_batch_throughput/v1`) in the working directory: the serial
//! baseline, every (threads × batch) sweep row, and the best multi-thread
//! throughput with its speedup over serial. CI uploads the file next to
//! `BENCH_hotpath.json`.
//!
//! Pass `--perf-out <path>` (after `--`) to additionally export a
//! `tulip.perf_report/v1` JSON for the full-batch multi-thread run:
//! `cargo bench --bench batch_throughput -- --perf-out perf-report.json`

use std::time::Instant;
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::Model;
use tulip::coordinator::{BatchExecutor, BatchRequest, PerfReport};
use tulip::metrics::MetricsRegistry;
use tulip::util::bench::print_table;

fn perf_out_arg() -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--perf-out" => return args.next(),
            _ if a.starts_with("--perf-out=") => {
                return Some(a["--perf-out=".len()..].to_string())
            }
            _ => {}
        }
    }
    None
}

/// One sweep configuration's measured throughput.
struct SweepRow {
    threads: usize,
    batch: usize,
    wall_ms: f64,
    images_per_sec: f64,
}

fn write_report(serial_ips: f64, rows: &[SweepRow], best_ips: f64) {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"tulip.bench_batch_throughput/v1\",\n");
    s.push_str(&format!("  \"serial_images_per_sec\": {serial_ips:.2},\n  \"cases\": [\n"));
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"threads\": {}, \"batch\": {}, \"wall_ms\": {:.1}, \
             \"images_per_sec\": {:.2}, \"speedup_vs_serial\": {:.2}}}{}\n",
            r.threads,
            r.batch,
            r.wall_ms,
            r.images_per_sec,
            r.images_per_sec / serial_ips,
            comma
        ));
    }
    s.push_str("  ],\n");
    s.push_str(&format!("  \"best_images_per_sec\": {best_ips:.2},\n"));
    s.push_str(&format!("  \"best_speedup_vs_serial\": {:.2}\n}}\n", best_ips / serial_ips));
    std::fs::write("BENCH_batch_throughput.json", &s).expect("write BENCH_batch_throughput.json");
    println!("wrote BENCH_batch_throughput.json (best {:.2}x serial)", best_ips / serial_ips);
}

fn make_exec(threads: usize) -> BatchExecutor {
    // The built-in "tiny" demo model: tiny_bnn(16, 8, 4) with the same
    // deterministic weights every serving component builds.
    let model = Model::demo("tiny").expect("built-in demo model");
    // 8 PEs per worker: plenty for the tiny net's widest layer and cheap
    // to replicate per thread. All executors share the global program
    // cache, exactly like production serving would.
    BatchExecutor::for_model(&model).unwrap().with_array(2, 4).with_threads(threads)
}

fn main() {
    const TOTAL: u64 = 64;
    let images: Vec<BitTensor> = (0..TOTAL).map(|i| BitTensor::random(16, 16, 8, i)).collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host parallelism: {cores} cores, workload: {TOTAL} images of 16x16x8, TinyBNN");

    // Warm the shared program cache once: schedule planning is a
    // per-process cost, not a per-batch cost.
    let warm = make_exec(1);
    warm.run(&BatchRequest::new(vec![images[0].clone()])).unwrap();

    // --- Serial baseline: one image per request, one worker --------------
    let serial_exec = make_exec(1);
    let t0 = Instant::now();
    let mut serial_scores: Vec<Vec<i64>> = Vec::with_capacity(images.len());
    for (i, img) in images.iter().enumerate() {
        serial_scores.push(serial_exec.run_one(i, img).unwrap().scores);
    }
    let serial_dt = t0.elapsed();
    let serial_ips = images.len() as f64 / serial_dt.as_secs_f64();
    println!(
        "serial baseline: {:.2} images/s ({:.1} ms total, single worker, batch=1)",
        serial_ips,
        serial_dt.as_secs_f64() * 1e3
    );

    // --- Sweep: batch size × worker count --------------------------------
    let mut rows = Vec::new();
    let mut best_ips = 0.0f64;
    let mut thread_counts = vec![1usize, 2, 4];
    if !thread_counts.contains(&cores) {
        thread_counts.push(cores);
    }
    for &threads in &thread_counts {
        let exec = make_exec(threads);
        for &batch in &[8usize, 32, TOTAL as usize] {
            let req = BatchRequest::new(images[..batch].to_vec());
            let t0 = Instant::now();
            let result = exec.run(&req).unwrap();
            let dt = t0.elapsed();
            let ips = batch as f64 / dt.as_secs_f64();
            if threads > 1 {
                best_ips = best_ips.max(ips);
            }
            // Determinism: every configuration reproduces the serial scores.
            for (i, r) in result.images.iter().enumerate() {
                assert_eq!(r.scores, serial_scores[i], "threads={threads} batch={batch} image={i}");
            }
            rows.push(SweepRow {
                threads,
                batch,
                wall_ms: dt.as_secs_f64() * 1e3,
                images_per_sec: ips,
            });
        }
    }
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.threads.to_string(),
                r.batch.to_string(),
                format!("{:.1}", r.wall_ms),
                format!("{:.2}", r.images_per_sec),
                format!("{:.2}X", r.images_per_sec / serial_ips),
            ]
        })
        .collect();
    print_table(
        "Batched bit-true inference (outputs verified bit-identical to serial)",
        &["threads", "batch", "wall (ms)", "images/s", "vs serial"],
        &table,
    );
    write_report(serial_ips, &rows, best_ips);

    // --- Optional PerfReport export --------------------------------------
    if let Some(path) = perf_out_arg() {
        let exec = make_exec(cores);
        let result = exec.run(&BatchRequest::new(images.clone())).unwrap();
        let report = PerfReport::from_batch(&exec, &result)
            .with_metrics(MetricsRegistry::global().snapshot());
        report.write_json(&path).unwrap();
        println!("\nperf report ({} images, {cores} workers) written to {path}", images.len());
    }

    let ratio = best_ips / serial_ips;
    println!(
        "\nbest multi-thread batched throughput: {best_ips:.2} images/s = {ratio:.2}X serial \
         ({})",
        if ratio >= 3.0 {
            "PASS: >= 3X"
        } else if cores < 4 {
            "host has < 4 cores; 3X target needs a multi-core runner"
        } else {
            "below the 3X target — investigate"
        }
    );
}

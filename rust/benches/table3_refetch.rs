//! Table III regeneration: input-fetch requirements (P, Z, P×Z) for
//! AlexNet layers on YodaNN and TULIP, checked cell-for-cell against the
//! paper, plus BinaryNet for completeness.
//!
//! Run: `cargo bench --bench table3_refetch`

use tulip::bnn::{alexnet, binarynet_cifar10};
use tulip::coordinator::table3;
use tulip::metrics;

fn main() {
    metrics::print_table3(&alexnet());

    // Cell-for-cell check against the paper's Table III.
    let expect = [
        ("conv1", 4usize, (1usize, 3usize), (1usize, 3usize)),
        ("conv2", 1, (2, 8), (2, 8)),
        ("conv3", 1, (4, 12), (8, 2)),
        ("conv4", 1, (6, 12), (12, 2)),
        ("conv5", 1, (6, 8), (12, 1)),
    ];
    let rows = table3(&alexnet());
    let mut all_match = true;
    for (row, (name, parts, (yp, yz), (tp, tz))) in rows.iter().zip(expect) {
        let ok = row.layer == name
            && row.parts == parts
            && (row.yodann.p, row.yodann.z) == (yp, yz)
            && (row.tulip.p, row.tulip.z) == (tp, tz);
        all_match &= ok;
        println!(
            "{name}: paper Y(P={yp},Z={yz}) T(P={tp},Z={tz})  ours Y(P={},Z={}) T(P={},Z={})  {}",
            row.yodann.p,
            row.yodann.z,
            row.tulip.p,
            row.tulip.z,
            if ok { "MATCH" } else { "MISMATCH" }
        );
    }
    println!(
        "\nTable III reproduction: {}",
        if all_match { "ALL 5 LAYERS MATCH THE PAPER EXACTLY" } else { "MISMATCH — investigate" }
    );

    // Binary-layer refetch-pressure improvement (paper: 3X to 4X).
    for row in rows.iter().filter(|r| r.kind == "Binary") {
        println!(
            "{}: P*Z improvement {:.1}X (paper range 3-4X)",
            row.layer,
            row.yodann.refetch_pressure() as f64 / row.tulip.refetch_pressure() as f64
        );
    }

    println!("\nBinaryNet-CIFAR10 (not in the paper's Table III — added for coverage):");
    metrics::print_table3(&binarynet_cifar10());
}

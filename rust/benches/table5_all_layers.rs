//! Table V regeneration: YodaNN vs TULIP on the **entire** BNNs (conv +
//! fully connected), with the paper's numbers alongside. The FC layers are
//! weight-stream-bound on both designs, which is why the end-to-end gain
//! (paper: 2.7× / 2.4×) is lower than the conv-only gain (3.0×).
//!
//! Run: `cargo bench --bench table5_all_layers`

use tulip::bnn::{alexnet, binarynet_cifar10};
use tulip::config::ArchConfig;
use tulip::coordinator::NetworkPerf;
use tulip::metrics;

fn main() {
    let paper = [
        ("BinaryNet", (495.2, 183.9, 27.5, 28.9, 2.1, 5.6)),
        ("AlexNet", (1013.3, 427.5, 176.8, 165.0, 2.1, 5.1)),
    ];

    for (net, (name, p)) in [binarynet_cifar10(), alexnet()].into_iter().zip(paper) {
        let c = metrics::print_comparison(&net, false);
        let (ey, et, ty, tt, fy, ft) = p;
        println!(
            "paper:   Y {ey:.1} uJ / {ty:.1} ms / {fy:.1} TOp/s/W | T {et:.1} uJ / {tt:.1} ms / {ft:.1} TOp/s/W  (gain {:.1}X)",
            ft / fy
        );
        println!(
            "ours:    Y {:.1} uJ / {:.1} ms / {:.1} TOp/s/W | T {:.1} uJ / {:.1} ms / {:.1} TOp/s/W  (gain {:.1}X)\n",
            c.yodann.energy_uj,
            c.yodann.time_ms,
            c.yodann.tops_per_w,
            c.tulip.energy_uj,
            c.tulip.time_ms,
            c.tulip.tops_per_w,
            c.efficiency_gain()
        );
        let _ = name;
    }

    // FC-vs-conv split analysis (the §V-C explanation for the lower gain).
    for net in [binarynet_cifar10(), alexnet()] {
        let t = NetworkPerf::model(&net, &ArchConfig::tulip());
        let y = NetworkPerf::model(&net, &ArchConfig::yodann());
        let (tc, ta) = (t.conv_aggregate(), t.total_aggregate());
        let (yc, ya) = (y.conv_aggregate(), y.total_aggregate());
        println!(
            "{}: FC share of energy — TULIP {:.0}% | YodaNN {:.0}%  (memory dominates FC, §V-C)",
            net.name,
            (ta.energy_uj - tc.energy_uj) / ta.energy_uj * 100.0,
            (ya.energy_uj - yc.energy_uj) / ya.energy_uj * 100.0
        );
    }
}

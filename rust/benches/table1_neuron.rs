//! Table I regeneration: the hardware threshold-logic neuron vs its CMOS
//! standard-cell equivalent (area / power / delay, across corners), plus a
//! micro-benchmark of the simulator's cell model (the innermost hot path).
//!
//! Run: `cargo bench --bench table1_neuron`

use tulip::metrics;
use tulip::neuron::{table1_improvements, HwNeuron};
use tulip::util::bench::bench;

fn main() {
    metrics::print_table1();

    let (a, p, d) = table1_improvements();
    println!("\npaper Table I X column: 1.8X area, 1.5X power, 1.8X delay");
    println!("measured              : {a:.1}X area, {p:.1}X power, {d:.1}X delay");

    // Simulator micro-bench: threshold-cell evaluation rate (feeds the
    // bit-true engine's roofline — see EXPERIMENTS.md §Perf).
    let mut n = HwNeuron::new();
    let mut i = 0u64;
    bench("hw_neuron.clock (cell model eval)", 7, || {
        i = i.wrapping_add(1);
        n.clock(i & 1 != 0, i & 2 != 0, i & 4 != 0, i & 8 != 0, (i % 6) as i32)
    });
}

//! Table IV regeneration: YodaNN vs TULIP on the convolution layers of
//! BinaryNet-CIFAR10 and AlexNet-ImageNet (Op, GOp/s, Energy, Time,
//! TOp/s/W), with the paper's numbers printed alongside.
//!
//! Run: `cargo bench --bench table4_conv`

use tulip::bnn::{alexnet, binarynet_cifar10};
use tulip::metrics;
use tulip::util::bench::bench;

struct PaperRow {
    energy_y: f64,
    energy_t: f64,
    time_y: f64,
    time_t: f64,
    eff_y: f64,
    eff_t: f64,
}

fn main() {
    let paper = [
        (
            "BinaryNet",
            PaperRow {
                energy_y: 472.6,
                energy_t: 159.1,
                time_y: 21.4,
                time_t: 20.6,
                eff_y: 2.2,
                eff_t: 6.4,
            },
        ),
        (
            "AlexNet",
            PaperRow {
                energy_y: 678.8,
                energy_t: 224.5,
                time_y: 28.1,
                time_t: 25.9,
                eff_y: 3.0,
                eff_t: 9.1,
            },
        ),
    ];

    for (net, p) in [binarynet_cifar10(), alexnet()].into_iter().zip(&paper) {
        let c = metrics::print_comparison(&net, true);
        let (_, row) = p;
        println!(
            "paper:   Y {:.1} uJ / {:.1} ms / {:.1} TOp/s/W | T {:.1} uJ / {:.1} ms / {:.1} TOp/s/W  (gain {:.1}X)",
            row.energy_y,
            row.time_y,
            row.eff_y,
            row.energy_t,
            row.time_t,
            row.eff_t,
            row.eff_t / row.eff_y
        );
        println!(
            "ours:    Y {:.1} uJ / {:.1} ms / {:.1} TOp/s/W | T {:.1} uJ / {:.1} ms / {:.1} TOp/s/W  (gain {:.1}X)",
            c.yodann.energy_uj,
            c.yodann.time_ms,
            c.yodann.tops_per_w,
            c.tulip.energy_uj,
            c.tulip.time_ms,
            c.tulip.tops_per_w,
            c.efficiency_gain()
        );
        println!(
            "shape:   energy-efficiency winner {} (paper: TULIP), gain {:.1}X vs paper {:.1}X\n",
            if c.efficiency_gain() > 1.0 { "TULIP" } else { "YodaNN" },
            c.efficiency_gain(),
            row.eff_t / row.eff_y
        );
    }

    // Model-evaluation throughput (the L3 analytic engine itself).
    let net = alexnet();
    bench("NetworkPerf::model(AlexNet, TULIP)", 5, || {
        tulip::coordinator::NetworkPerf::model(&net, &tulip::config::ArchConfig::tulip())
            .conv_aggregate()
            .cycles
    });
}

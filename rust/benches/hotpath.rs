//! L3 hot-path benchmarks — the profiling substrate for EXPERIMENTS.md
//! §Perf. Covers every loop the coordinator or the bit-true engine sits
//! in: PE stepping, schedule generation (cached and uncached), bit-true
//! layer execution, and the full analytic network model.
//!
//! Run: `cargo bench --bench hotpath`

use tulip::arch::unit::PeArray;
use tulip::bnn::layer::LayerKind;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{alexnet, binarynet_cifar10, Layer};
use tulip::config::ArchConfig;
use tulip::coordinator::NetworkPerf;
use tulip::pe::TulipPe;
use tulip::scheduler::adder_tree;
use tulip::scheduler::seqgen::{OpDesc, SequenceGenerator};
use tulip::sim::cycle;
use tulip::util::bench::bench;

fn main() {
    // --- 1. PE micro-step (the innermost bit-true loop) -----------------
    let mut sg = SequenceGenerator::new();
    let prog = sg.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 });
    let word = &prog.schedule.words[10];
    let mut pe = TulipPe::new();
    bench("pe.step (single control word)", 7, || {
        pe.step(word, &[]);
        pe.neuron_out(0)
    });

    // --- 2. Whole-node bit-true execution -------------------------------
    let products = BitTensor::random(1, 1, 288, 3).data;
    bench("bit-true 288-node (384 cycles)", 7, || {
        let mut pe = TulipPe::new();
        prog.schedule.run_on(&mut pe, &products);
        pe.neuron_out(prog.out_neuron.unwrap())
    });

    // --- 3. Schedule generation: uncached vs cached ----------------------
    bench("threshold_node(288) generation (uncached)", 5, || {
        adder_tree::threshold_node(288, 144).total_cycles()
    });
    bench("threshold_node(1023) generation (uncached)", 5, || {
        adder_tree::threshold_node(1023, 512).total_cycles()
    });
    let mut sg2 = SequenceGenerator::new();
    let _ = sg2.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 });
    bench("seqgen.program(288) (cached)", 7, || {
        sg2.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 }).schedule.cycles()
    });
    // A realistic conv-layer setup: 64 channels, 64 distinct thresholds —
    // the shared-tree optimization makes the marginal threshold a
    // clone+append instead of a full backtracking re-plan.
    bench("seqgen: 64 distinct thresholds (n=288)", 5, || {
        let mut sg = SequenceGenerator::new();
        let mut total = 0usize;
        for t in 100..164 {
            total += sg.program(&OpDesc::ThresholdNode { n: 288, t_popcount: t }).schedule.cycles();
        }
        total
    });

    // --- 4. Bit-true conv layer on an 8-PE array -------------------------
    let layer = Layer::conv("b", LayerKind::ConvBin, (8, 8, 16), 3, 1, 1, 8, None);
    let input = BitTensor::random(8, 8, 16, 5);
    let weights = BinWeights::random(8, layer.fanin(), 6);
    bench("bit-true conv 8x8x16 -> 8ch (8 PEs)", 5, || {
        let mut array = PeArray::new(2, 4);
        let mut sg = SequenceGenerator::new();
        cycle::conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights).cycles
    });

    // --- 5. Analytic model over full networks ---------------------------
    let bn = binarynet_cifar10();
    let an = alexnet();
    bench("NetworkPerf::model(BinaryNet, TULIP)", 5, || {
        NetworkPerf::model(&bn, &ArchConfig::tulip()).total_aggregate().cycles
    });
    bench("NetworkPerf::model(AlexNet, both archs)", 5, || {
        let t = NetworkPerf::model(&an, &ArchConfig::tulip()).total_aggregate().cycles;
        let y = NetworkPerf::model(&an, &ArchConfig::yodann()).total_aggregate().cycles;
        t + y
    });

    // --- 6. Register-allocation planner (the backtracking search) -------
    // 1023 is the PE's documented fan-in ceiling (§IV-C "up to 10-bit
    // addition"); larger fan-ins are chunked by the coordinator.
    bench("plan+emit sum_tree(1023)", 5, || adder_tree::sum_tree(1023).0.cycles());
}

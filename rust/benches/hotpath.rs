//! L3 hot-path benchmarks — the profiling substrate for EXPERIMENTS.md
//! §Perf. Covers every loop the coordinator or the bit-true engine sits
//! in: PE stepping, schedule generation (cached and uncached), bit-true
//! layer execution, the full analytic network model, and the scalar vs
//! bit-sliced forward-pass comparison that gates the lane-parallel engine.
//!
//! Run: `cargo bench --bench hotpath`
//!
//! Emits `BENCH_hotpath.json` (schema `tulip.bench_hotpath/v1`) in the
//! working directory: every case's median ns plus a `forward` block with
//! scalar vs bit-sliced ns/image and the resulting speedup. CI uploads the
//! file as the `bench-hotpath` artifact.

use tulip::arch::unit::{PeArray, SlicedArray};
use tulip::bnn::layer::LayerKind;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{alexnet, binarynet_cifar10, tiny_bnn, Layer, Model};
use tulip::config::ArchConfig;
use tulip::coordinator::NetworkPerf;
use tulip::pe::TulipPe;
use tulip::scheduler::adder_tree;
use tulip::scheduler::seqgen::{OpDesc, SequenceGenerator};
use tulip::sim::cycle;
use tulip::util::bench::{bench, BenchResult};

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn write_report(cases: &[BenchResult], scalar_ns: f64, sliced_ns: f64) {
    let mut s = String::new();
    s.push_str("{\n  \"schema\": \"tulip.bench_hotpath/v1\",\n  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let comma = if i + 1 == cases.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"name\": {}, \"median_ns\": {:.1}}}{}\n",
            json_str(&c.name),
            c.median_ns(),
            comma
        ));
    }
    s.push_str("  ],\n  \"forward\": {\n");
    s.push_str(&format!("    \"scalar_ns_per_image\": {scalar_ns:.1},\n"));
    s.push_str(&format!("    \"bit_sliced_ns_per_image\": {sliced_ns:.1},\n"));
    s.push_str(&format!("    \"speedup\": {:.2}\n", scalar_ns / sliced_ns));
    s.push_str("  }\n}\n");
    std::fs::write("BENCH_hotpath.json", &s).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json (speedup {:.2}x)", scalar_ns / sliced_ns);
}

fn main() {
    let mut cases: Vec<BenchResult> = Vec::new();

    // --- 1. PE micro-step (the innermost bit-true loop) -----------------
    let mut sg = SequenceGenerator::new();
    let prog = sg.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 });
    let word = &prog.schedule.words[10];
    let mut pe = TulipPe::new();
    cases.push(bench("pe.step (single control word)", 7, || {
        pe.step(word, &[]);
        pe.neuron_out(0)
    }));

    // --- 2. Whole-node bit-true execution -------------------------------
    let products = BitTensor::random(1, 1, 288, 3).data;
    cases.push(bench("bit-true 288-node (384 cycles)", 7, || {
        let mut pe = TulipPe::new();
        prog.schedule.run_on(&mut pe, &products);
        pe.neuron_out(prog.out_neuron.unwrap())
    }));

    // --- 3. Schedule generation: uncached vs cached ----------------------
    cases.push(bench("threshold_node(288) generation (uncached)", 5, || {
        adder_tree::threshold_node(288, 144).total_cycles()
    }));
    cases.push(bench("threshold_node(1023) generation (uncached)", 5, || {
        adder_tree::threshold_node(1023, 512).total_cycles()
    }));
    let mut sg2 = SequenceGenerator::new();
    let _ = sg2.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 });
    cases.push(bench("seqgen.program(288) (cached)", 7, || {
        sg2.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 }).schedule.cycles()
    }));
    // A realistic conv-layer setup: 64 channels, 64 distinct thresholds —
    // the shared-tree optimization makes the marginal threshold a
    // clone+append instead of a full backtracking re-plan.
    cases.push(bench("seqgen: 64 distinct thresholds (n=288)", 5, || {
        let mut sg = SequenceGenerator::new();
        let mut total = 0usize;
        for t in 100..164 {
            total += sg.program(&OpDesc::ThresholdNode { n: 288, t_popcount: t }).schedule.cycles();
        }
        total
    }));

    // --- 4. Bit-true conv layer on an 8-PE array -------------------------
    let layer = Layer::conv("b", LayerKind::ConvBin, (8, 8, 16), 3, 1, 1, 8, None);
    let input = BitTensor::random(8, 8, 16, 5);
    let weights = BinWeights::random(8, layer.fanin(), 6);
    cases.push(bench("bit-true conv 8x8x16 -> 8ch (8 PEs)", 5, || {
        let mut array = PeArray::new(2, 4);
        let mut sg = SequenceGenerator::new();
        cycle::conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights).cycles
    }));

    // --- 5. Analytic model over full networks ---------------------------
    let bn = binarynet_cifar10();
    let an = alexnet();
    cases.push(bench("NetworkPerf::model(BinaryNet, TULIP)", 5, || {
        NetworkPerf::model(&bn, &ArchConfig::tulip()).total_aggregate().cycles
    }));
    cases.push(bench("NetworkPerf::model(AlexNet, both archs)", 5, || {
        let t = NetworkPerf::model(&an, &ArchConfig::tulip()).total_aggregate().cycles;
        let y = NetworkPerf::model(&an, &ArchConfig::yodann()).total_aggregate().cycles;
        t + y
    }));

    // --- 6. Register-allocation planner (the backtracking search) -------
    // 1023 is the PE's documented fan-in ceiling (§IV-C "up to 10-bit
    // addition"); larger fan-ins are chunked by the coordinator.
    cases.push(bench("plan+emit sum_tree(1023)", 5, || adder_tree::sum_tree(1023).0.cycles()));

    // --- 7. Scalar vs bit-sliced whole-network forward pass --------------
    // The tentpole comparison: one image through tiny_bnn(16, 8, 10) on the
    // same warm program cache, scalar reference engine vs the 64-lane SWAR
    // engine. Both closures reuse the array (forward_* resets stats on
    // entry), so the measurement is pure execution, not setup.
    let model = Model::random(tiny_bnn(16, 8, 10), 40).expect("demo network is valid");
    let image = BitTensor::random(16, 16, 8, 77);
    let mut sg_fwd = SequenceGenerator::new();
    let mut sg_sliced = SequenceGenerator::with_cache(sg_fwd.cache());
    let mut array = PeArray::new(2, 4);
    let mut arr = SlicedArray::new(2, 4);
    let scalar = bench("forward tiny_bnn(16,8,10) scalar", 5, || {
        model.forward_scalar(&mut array, &mut sg_fwd, &image).cycles
    });
    let sliced = bench("forward tiny_bnn(16,8,10) bit-sliced", 5, || {
        model.forward_sliced(&mut arr, &mut sg_sliced, &image).cycles
    });
    println!(
        "\nforward speedup (scalar / bit-sliced): {:.2}x",
        scalar.median_ns() / sliced.median_ns()
    );
    let (scalar_ns, sliced_ns) = (scalar.median_ns(), sliced.median_ns());
    cases.push(scalar);
    cases.push(sliced);
    write_report(&cases, scalar_ns, sliced_ns);
}

//! Fig. 7 regeneration: the area rollup of the TULIP layout in TSMC
//! 40nm-LP, checked against the paper's floorplan numbers, plus the
//! PE-deployment claim ("TULIP can deploy an order of magnitude more PEs
//! ... for the same chip area").
//!
//! Run: `cargo bench --bench fig7_area`

use tulip::energy::{calib, tulip_area, yodann_area};
use tulip::metrics;

fn main() {
    metrics::print_fig7();

    let t = tulip_area();
    println!("\npaper Fig. 7 anchors:");
    let checks = [
        ("die area (mm^2)", t.total_mm2(), calib::DIE_AREA_MM2),
        ("image buffer (um^2)", t.image_buffer_um2, 680e3),
        ("kernel buffer (um^2)", t.kernel_buffer_um2, 293e3),
        ("controller (um^2)", t.controller_um2, 4.52e3),
        ("processing (um^2)", t.processing_um2, 656e3),
    ];
    for (name, ours, paper) in checks {
        let delta = (ours - paper).abs() / paper * 100.0;
        println!("  {name:<22} ours {ours:>12.2}  paper {paper:>12.2}  delta {delta:.1}%");
    }

    // §VI: "TULIP can deploy an order of magnitude more PEs as compared to
    // a MAC-based architecture for the same chip area."
    let pes_per_mac_area = calib::MAC_AREA_UM2 / calib::PE_AREA_UM2;
    println!(
        "\nPEs per full-MAC footprint: {pes_per_mac_area:.1} (paper: 23.18X area ratio ⇒ 'an order of magnitude more PEs')"
    );
    let y = yodann_area();
    println!(
        "chip-area parity: TULIP {:.2} mm^2 vs YodaNN {:.2} mm^2 ({:+.1}%)",
        t.total_mm2(),
        y.total_mm2(),
        (t.total_mm2() / y.total_mm2() - 1.0) * 100.0
    );

    // Chip average power anchor (Fig. 7: 23.9 mW).
    println!(
        "paper chip power: {:.1} mW; our modelled TULIP average over BinaryNet conv: see table4_conv",
        calib::CHIP_POWER_MW
    );
}

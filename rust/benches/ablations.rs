//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Adder scheme** — the paper's footnote 3: 2-/3-bit carry-lookahead
//!    threshold cells vs the evaluated full-adder cascade.
//! 2. **PE count** — the §I scalability claim.
//! 3. **Network generality** — "the gains are consistent across different
//!    neural networks" (§V-C), checked over four workloads including two
//!    (MNIST MLP, SVHN) beyond the paper's evaluation.
//! 4. **Overlap policy** — fetch/compute overlap (double-buffered L2) vs a
//!    serialized upper bound.
//!
//! Run: `cargo bench --bench ablations`

use tulip::bnn::{alexnet, binarynet_cifar10, mnist_mlp, svhn_net};
use tulip::config::ArchConfig;
use tulip::coordinator::NetworkPerf;
use tulip::scheduler::cla::{ablation, AdderScheme};
use tulip::util::bench::print_table;

fn main() {
    // ---- 1. Carry-lookahead cells (footnote 3) -------------------------
    for n in [288usize, 1152, 1023] {
        let rows: Vec<Vec<String>> = ablation(n)
            .iter()
            .map(|r| {
                vec![
                    r.scheme.to_string(),
                    r.node_cycles.to_string(),
                    format!("{:.2}X", r.speedup_vs_fa),
                    format!("{:.2}X", r.area_factor),
                    format!("{:.2}X", r.energy_factor),
                ]
            })
            .collect();
        print_table(
            &format!("Ablation: adder scheme, {n}-input node"),
            &["scheme", "cycles", "speedup", "cell area", "node energy"],
            &rows,
        );
    }
    println!(
        "CLA-2 gives ~1.6X node throughput for ~1.3X cell area at near-parity\n\
         energy — consistent with the paper's 'increase the throughput at the\n\
         expense of a small increase in area and power' (footnote 3)."
    );

    // ---- 2. PE scaling ---------------------------------------------------
    let net = binarynet_cifar10();
    let mut rows = Vec::new();
    let base = NetworkPerf::model(&net, &ArchConfig::tulip().with_pes(64)).conv_aggregate();
    for pes in [64usize, 128, 256, 512, 1024] {
        let c = NetworkPerf::model(&net, &ArchConfig::tulip().with_pes(pes)).conv_aggregate();
        rows.push(vec![
            pes.to_string(),
            format!("{:.1}", c.gops),
            format!("{:.2}X", c.gops / base.gops),
            format!("{:.2}", c.tops_per_w),
        ]);
    }
    print_table(
        "Ablation: PE count (BinaryNet conv) — §I 'throughput increases linearly'",
        &["PEs", "GOp/s", "scaling", "TOp/s/W"],
        &rows,
    );

    // ---- 3. Generality across networks ----------------------------------
    let mut rows = Vec::new();
    for net in [binarynet_cifar10(), alexnet(), svhn_net(), mnist_mlp()] {
        let t = NetworkPerf::model(&net, &ArchConfig::tulip());
        let y = NetworkPerf::model(&net, &ArchConfig::yodann());
        let (ta, ya) = (t.total_aggregate(), y.total_aggregate());
        rows.push(vec![
            format!("{}/{}", net.name, net.dataset),
            format!("{:.0}", ta.mops),
            format!("{:.2}", ya.tops_per_w),
            format!("{:.2}", ta.tops_per_w),
            format!("{:.2}X", ta.tops_per_w / ya.tops_per_w),
        ]);
    }
    print_table(
        "Ablation: network generality (all layers)",
        &["network", "MOp", "YodaNN TOp/s/W", "TULIP TOp/s/W", "gain"],
        &rows,
    );
    println!(
        "The MLP (FC-only) gain collapses toward 1X — FC layers are weight-\n\
         stream-bound on both designs, the §V-C effect in its pure form."
    );

    // ---- 3b. Integer layers on PEs vs MACs (the §V-C steering decision) --
    use tulip::coordinator::exec::{pe_int_node_cycles, pe_node_cost};
    use tulip::scheduler::seqgen::SequenceGenerator;
    let mut sg = SequenceGenerator::new();
    let mut rows = Vec::new();
    for bits in [1u32, 4, 8, 12] {
        let cycles = if bits == 1 {
            pe_node_cost(&mut sg, 288, 288).cycles
        } else {
            pe_int_node_cycles(288, bits)
        };
        rows.push(vec![
            bits.to_string(),
            cycles.to_string(),
            format!("{:.0}X", cycles as f64 / 17.0),
        ]);
    }
    print_table(
        "Ablation: 288-input node on a TULIP-PE by activation width (MAC = 17 cy)",
        &["activation bits", "PE cycles", "vs MAC"],
        &rows,
    );
    println!(
        "At 12-bit activations the PE is >200X slower than the MAC — the\n\
         quantified version of §V-C's 'hence, MACs are used for integer layers'."
    );

    // ---- 4. Fetch/compute overlap ---------------------------------------
    let mut rows = Vec::new();
    for net in [binarynet_cifar10(), alexnet()] {
        let t = NetworkPerf::model(&net, &ArchConfig::tulip());
        let overlapped: u64 = t.layers.iter().map(|l| l.total_cycles).sum();
        let serialized: u64 = t.layers.iter().map(|l| l.compute_cycles + l.fetch_cycles).sum();
        rows.push(vec![
            net.name.clone(),
            overlapped.to_string(),
            serialized.to_string(),
            format!("{:.2}X", serialized as f64 / overlapped as f64),
        ]);
    }
    print_table(
        "Ablation: double-buffered L2 overlap vs serialized fetch+compute (TULIP)",
        &["network", "overlapped (cy)", "serialized (cy)", "overlap gain"],
        &rows,
    );
}

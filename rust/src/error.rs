//! `error` — the crate-wide typed error.
//!
//! Every fallible public API in the crate reports failures through
//! [`Error`]: network/schedule validation, model-artifact parsing, serve
//! routing and the wire protocol. The enum implements
//! [`std::error::Error`], so it composes with `anyhow` (the crate-level
//! [`Result`](crate::Result) alias) via `?` — callers that want typed
//! matching get it, callers that just want context-chained reporting lose
//! nothing.
//!
//! Stringly-typed errors (`Result<_, String>`) are gone from the public
//! API as of the `Model` redesign; the variants below partition the
//! failure domains instead.

use std::fmt;

/// The crate-wide error type.
///
/// Variants partition by failure domain rather than by module, so a
/// caller can match on *what went wrong* (bad artifact vs. unknown model
/// vs. malformed wire line) without knowing which layer detected it.
#[derive(Debug)]
#[non_exhaustive]
pub enum Error {
    /// A [`Network`](crate::bnn::Network) is internally inconsistent
    /// (layer chaining, weight shapes, empty layer list).
    InvalidNetwork(String),
    /// A schedule or control word violates a hardware constraint
    /// (see [`ControlWord::validate`](crate::pe::ControlWord::validate)).
    InvalidSchedule(String),
    /// An input tensor does not match the shape a network expects.
    ShapeMismatch(String),
    /// A structurally valid model cannot run on the serving engines
    /// (e.g. integer first layer, non-FC head).
    Unservable(String),
    /// A serve request referenced a model name the registry doesn't hold.
    UnknownModel(String),
    /// `load_model` for a name that is already registered.
    DuplicateModel(String),
    /// A `tulip.model/v1` document is malformed (bad JSON, missing or
    /// mistyped field, wrong weight-blob length).
    ModelFormat(String),
    /// A model artifact declares a schema this build doesn't speak.
    UnsupportedVersion {
        /// The `schema` string found in the document.
        found: String,
        /// The schema string this build expects.
        expected: &'static str,
    },
    /// An I/O failure while reading or writing an artifact.
    Io {
        /// The path being read or written.
        path: String,
        /// The underlying OS error.
        source: std::io::Error,
    },
    /// A malformed client line on the serve wire. `id` is the request id
    /// if one was parsed (0 otherwise) so the error reply can blame it.
    Protocol {
        /// Request id to blame in the error reply (0 if unknown).
        id: u64,
        /// Human-readable description of the parse failure.
        msg: String,
    },
}

impl Error {
    /// The request id a protocol error should be blamed on (0 when the
    /// failure is not tied to a specific request).
    pub fn request_id(&self) -> u64 {
        match self {
            Error::Protocol { id, .. } => *id,
            _ => 0,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidNetwork(m) => write!(f, "invalid network: {m}"),
            Error::InvalidSchedule(m) => write!(f, "invalid schedule: {m}"),
            Error::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            Error::Unservable(m) => write!(f, "model not servable: {m}"),
            Error::UnknownModel(n) => write!(f, "unknown model '{n}'"),
            Error::DuplicateModel(n) => write!(f, "model '{n}' already loaded"),
            Error::ModelFormat(m) => write!(f, "bad model document: {m}"),
            Error::UnsupportedVersion { found, expected } => {
                write!(f, "unsupported model schema '{found}' (expected '{expected}')")
            }
            Error::Io { path, source } => write!(f, "{path}: {source}"),
            Error::Protocol { id, msg } => write!(f, "protocol error (id {id}): {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_source() {
        let e = Error::UnknownModel("alex".into());
        assert_eq!(e.to_string(), "unknown model 'alex'");
        assert!(std::error::Error::source(&e).is_none());
        let io = Error::Io {
            path: "/tmp/x".into(),
            source: std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        };
        assert!(io.to_string().contains("/tmp/x"));
        assert!(std::error::Error::source(&io).is_some());
    }

    #[test]
    fn request_id_blame() {
        assert_eq!(Error::Protocol { id: 7, msg: "x".into() }.request_id(), 7);
        assert_eq!(Error::ShapeMismatch("x".into()).request_id(), 0);
    }

    #[test]
    fn converts_into_anyhow() {
        fn f() -> crate::Result<()> {
            Err(Error::Unservable("integer first layer".into()))?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("not servable"));
    }
}

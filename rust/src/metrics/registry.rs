//! A structured, thread-safe metrics registry: counters, gauges and
//! log₂-bucket histograms.
//!
//! Every instrument is an `Arc`-shared handle over lock-free atomics, so
//! the hot paths of the batch engine (`coordinator::batch`), the schedule
//! cache (`scheduler::cache`), the PE simulator and the energy model can
//! all report into one registry without contending on a lock: the registry
//! map is only locked when an instrument is first created (or a snapshot
//! is taken), never per update. Names are plain dot-separated strings
//! (`"batch.images"`, `"scheduler.cache.hits"`); the registry keeps them
//! sorted so snapshots — and the JSON they serialize to — are
//! deterministic.
//!
//! ```
//! use tulip::metrics::MetricsRegistry;
//!
//! let reg = MetricsRegistry::new();
//! let images = reg.counter("batch.images");
//! images.add(32);
//! assert_eq!(images.get(), 32);
//!
//! let wall = reg.histogram("batch.wall_us");
//! wall.observe(1500);
//! wall.observe(900);
//! let snap = reg.snapshot();
//! assert_eq!(snap.counters, vec![("batch.images".to_string(), 32)]);
//! assert_eq!(snap.histograms[0].1.count, 2);
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

/// A monotonically increasing counter handle (cheap to clone; all clones
/// share one atomic).
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A set-to-latest gauge handle holding an `f64` (stored as raw bits in an
/// atomic, so updates are lock-free).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge to `v`.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Add `v` to the gauge (compare-and-swap loop; gauges are updated
    /// rarely — per batch, not per image — so contention is negligible).
    pub fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Increment by one (occupancy-style gauges, e.g. `serve.queue_depth`).
    #[inline]
    pub fn inc(&self) {
        self.add(1.0);
    }

    /// Decrement by one.
    #[inline]
    pub fn dec(&self) {
        self.add(-1.0);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of log₂ buckets: bucket 0 holds the value 0, bucket `b ≥ 1`
/// holds values with bit width `b`, i.e. `[2^(b-1), 2^b - 1]`.
const NUM_BUCKETS: usize = 65;

/// Shared state behind a [`Histogram`] handle.
#[derive(Debug)]
pub struct HistogramCore {
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; NUM_BUCKETS],
}

impl HistogramCore {
    fn new() -> Self {
        HistogramCore {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// A histogram handle over non-negative integer samples (typically
/// microseconds or cycles). Exact count/sum/min/max plus log₂ buckets for
/// quantile estimates; every update is a handful of relaxed atomic ops.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistogramCore::new()))
    }
}

impl Histogram {
    /// Record one sample.
    pub fn observe(&self, v: u64) {
        let c = &self.0;
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
        c.min.fetch_min(v, Ordering::Relaxed);
        c.max.fetch_max(v, Ordering::Relaxed);
        let bucket = (64 - v.leading_zeros()) as usize; // 0 for v == 0
        c.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time snapshot (consistent enough for reporting; individual
    /// fields are read independently of concurrent writers).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let c = &self.0;
        let count = c.count.load(Ordering::Relaxed);
        let min = c.min.load(Ordering::Relaxed);
        HistogramSnapshot {
            count,
            sum: c.sum.load(Ordering::Relaxed),
            min: if count == 0 { 0 } else { min },
            max: c.max.load(Ordering::Relaxed),
            buckets: c
                .buckets
                .iter()
                .enumerate()
                .filter_map(|(b, n)| {
                    let n = n.load(Ordering::Relaxed);
                    (n > 0).then_some((b as u32, n))
                })
                .collect(),
        }
    }
}

/// Frozen view of a [`Histogram`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Number of samples recorded.
    pub count: u64,
    /// Sum of all samples.
    pub sum: u64,
    /// Smallest sample (0 when empty).
    pub min: u64,
    /// Largest sample.
    pub max: u64,
    /// Non-empty log₂ buckets as `(bit_width, count)`; bit width 0 is the
    /// value 0, width `b` covers `[2^(b-1), 2^b - 1]`.
    pub buckets: Vec<(u32, u64)>,
}

impl HistogramSnapshot {
    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Fold another snapshot into this one (exact for count/sum/min/max;
    /// log₂ buckets merge by width). Used by the serve registry to roll
    /// per-model latency histograms up into server-wide totals.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if other.count == 0 {
            return;
        }
        self.min = if self.count == 0 { other.min } else { self.min.min(other.min) };
        self.max = self.max.max(other.max);
        self.count += other.count;
        self.sum += other.sum;
        for &(width, n) in &other.buckets {
            match self.buckets.binary_search_by_key(&width, |&(w, _)| w) {
                Ok(i) => self.buckets[i].1 += n,
                Err(i) => self.buckets.insert(i, (width, n)),
            }
        }
    }

    /// Quantile estimate from the log₂ buckets: returns the upper bound of
    /// the bucket containing the `q`-quantile sample, clamped to the exact
    /// observed `[min, max]`. Accurate to within a factor of 2 by
    /// construction — adequate for p50/p99 latency reporting.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0u64;
        for &(width, n) in &self.buckets {
            seen += n;
            if seen >= rank.max(1) {
                let upper =
                    if width == 0 { 0 } else { (1u64 << (width - 1)).saturating_mul(2) - 1 };
                return upper.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// Number of one-second slices in a [`WindowHistogram`] ring. 64 slices
/// comfortably cover the largest supported query window (60 s) while
/// keeping the slot lookup a cheap modulo.
const WINDOW_SLICES: usize = 64;

/// One per-second slice of a [`WindowHistogram`]: a full log₂ histogram
/// tagged with the absolute second it currently covers.
#[derive(Debug)]
struct WindowSlice {
    /// Absolute second this slice holds (`u64::MAX` = never used).
    second: AtomicU64,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; NUM_BUCKETS],
}

impl WindowSlice {
    fn new() -> Self {
        WindowSlice {
            second: AtomicU64::new(u64::MAX),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

/// Shared state behind a [`WindowHistogram`] handle.
#[derive(Debug)]
struct WindowCore {
    epoch: Instant,
    slices: [WindowSlice; WINDOW_SLICES],
}

/// A sliding-window histogram: a ring of per-second log₂ histogram slices,
/// so rolling quantiles (p50/p99 over the last 10 s or 60 s) stay
/// queryable live while the hot path remains lock-free — one tag check
/// plus the same handful of relaxed atomic ops as [`Histogram::observe`].
///
/// Slices are claimed per absolute second via compare-and-swap on the
/// slice's second tag; the claimant clears the stale counts before the
/// slice starts accumulating the new second. Windows larger than
/// [`WINDOW_SLICES`] (64 s) are clamped, which covers the 10 s and 60 s
/// SLO windows the serving stack exposes.
///
/// The `*_at` variants take the second as an argument so slice rotation is
/// testable against a simulated clock; `observe`/`snapshot_window` use the
/// handle's own monotonic clock.
#[derive(Debug, Clone)]
pub struct WindowHistogram(Arc<WindowCore>);

impl Default for WindowHistogram {
    fn default() -> Self {
        WindowHistogram(Arc::new(WindowCore {
            epoch: Instant::now(),
            slices: std::array::from_fn(|_| WindowSlice::new()),
        }))
    }
}

impl WindowHistogram {
    /// A fresh, empty window histogram whose clock starts now.
    pub fn new() -> Self {
        Self::default()
    }

    /// Seconds elapsed on this histogram's own monotonic clock.
    pub fn now_s(&self) -> u64 {
        self.0.epoch.elapsed().as_secs()
    }

    /// Record one sample at the current second.
    pub fn observe(&self, v: u64) {
        self.observe_at(self.now_s(), v);
    }

    /// Record one sample at the absolute second `sec` (simulated-clock
    /// variant; see the type docs).
    pub fn observe_at(&self, sec: u64, v: u64) {
        let slice = &self.0.slices[(sec % WINDOW_SLICES as u64) as usize];
        let tagged = slice.second.load(Ordering::Acquire);
        if tagged != sec {
            // First writer of a new second claims the slice and clears the
            // stale counts. Losing the claim race for the same second just
            // falls through to record; a straggler from an older second
            // lands in the newer slice — one sample attributed a ring-turn
            // late, acceptable for telemetry.
            let claim =
                slice.second.compare_exchange(tagged, sec, Ordering::AcqRel, Ordering::Acquire);
            if claim.is_ok() {
                slice.reset();
            }
        }
        slice.count.fetch_add(1, Ordering::Relaxed);
        slice.sum.fetch_add(v, Ordering::Relaxed);
        slice.min.fetch_min(v, Ordering::Relaxed);
        slice.max.fetch_max(v, Ordering::Relaxed);
        let bucket = (64 - v.leading_zeros()) as usize; // 0 for v == 0
        slice.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Merge the slices covering the trailing `window_s` seconds into one
    /// [`HistogramSnapshot`].
    pub fn snapshot_window(&self, window_s: u64) -> HistogramSnapshot {
        self.snapshot_window_at(self.now_s(), window_s)
    }

    /// Window snapshot as of the absolute second `now_s` (simulated-clock
    /// variant): merges every slice whose second lies in
    /// `(now_s - window_s, now_s]`.
    pub fn snapshot_window_at(&self, now_s: u64, window_s: u64) -> HistogramSnapshot {
        let window_s = window_s.min(WINDOW_SLICES as u64);
        let mut merged = HistogramSnapshot::default();
        for slice in &self.0.slices {
            let sec = slice.second.load(Ordering::Acquire);
            if sec > now_s || now_s - sec >= window_s {
                continue; // never used (u64::MAX tag), future, or aged out
            }
            let count = slice.count.load(Ordering::Relaxed);
            if count == 0 {
                continue;
            }
            let min = slice.min.load(Ordering::Relaxed);
            merged.merge(&HistogramSnapshot {
                count,
                sum: slice.sum.load(Ordering::Relaxed),
                // A slice mid-reset can expose the sentinel min; floor it.
                min: if min == u64::MAX { 0 } else { min },
                max: slice.max.load(Ordering::Relaxed),
                buckets: slice
                    .buckets
                    .iter()
                    .enumerate()
                    .filter_map(|(b, n)| {
                        let n = n.load(Ordering::Relaxed);
                        (n > 0).then_some((b as u32, n))
                    })
                    .collect(),
            });
        }
        merged
    }
}

/// The registry: a name-keyed set of [`Counter`]s, [`Gauge`]s,
/// [`Histogram`]s and [`WindowHistogram`]s. See the [module docs](self)
/// for the locking story.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: RwLock<BTreeMap<String, Counter>>,
    gauges: RwLock<BTreeMap<String, Gauge>>,
    histograms: RwLock<BTreeMap<String, Histogram>>,
    windows: RwLock<BTreeMap<String, WindowHistogram>>,
}

impl MetricsRegistry {
    /// A fresh, empty registry (for scoped accounting — e.g. one executor
    /// or one test — as opposed to the process-wide [`MetricsRegistry::global`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide registry every built-in instrument reports into by
    /// default: the batch executor, the shared program cache, the PE
    /// activity rollup and the energy model.
    pub fn global() -> &'static MetricsRegistry {
        static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
        GLOBAL.get_or_init(MetricsRegistry::default)
    }

    /// Get (or create) the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        if let Some(c) = self.counters.read().expect("metrics registry poisoned").get(name) {
            return c.clone();
        }
        let mut map = self.counters.write().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    /// Get (or create) the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        if let Some(g) = self.gauges.read().expect("metrics registry poisoned").get(name) {
            return g.clone();
        }
        let mut map = self.gauges.write().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    /// Get (or create) the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Histogram {
        if let Some(h) = self.histograms.read().expect("metrics registry poisoned").get(name) {
            return h.clone();
        }
        let mut map = self.histograms.write().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    /// Get (or create) the sliding-window histogram named `name`.
    pub fn window_histogram(&self, name: &str) -> WindowHistogram {
        if let Some(w) = self.windows.read().expect("metrics registry poisoned").get(name) {
            return w.clone();
        }
        let mut map = self.windows.write().expect("metrics registry poisoned");
        map.entry(name.to_string()).or_default().clone()
    }

    /// Every sliding-window histogram as `(name, handle)`, sorted by name.
    /// Window instruments are queried live — e.g. by the Prometheus
    /// exposition — rather than frozen into [`MetricsSnapshot`]s, which
    /// keeps the perf-report schema stable.
    pub fn window_histograms(&self) -> Vec<(String, WindowHistogram)> {
        self.windows
            .read()
            .expect("metrics registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Freeze every instrument into a sorted, deterministic snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self
                .counters
                .read()
                .expect("metrics registry poisoned")
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .read()
                .expect("metrics registry poisoned")
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .read()
                .expect("metrics registry poisoned")
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }
}

/// A frozen, name-sorted view of a [`MetricsRegistry`] — what perf reports
/// embed and serialize.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// `(name, value)` for every counter, sorted by name.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` for every gauge, sorted by name.
    pub gauges: Vec<(String, f64)>,
    /// `(name, snapshot)` for every histogram, sorted by name.
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc as StdArc;

    #[test]
    fn counters_sum_exactly_across_threads() {
        let reg = StdArc::new(MetricsRegistry::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let reg = StdArc::clone(&reg);
                std::thread::spawn(move || {
                    let c = reg.counter("t.ops");
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counter("t.ops").get(), 4000);
    }

    #[test]
    fn gauge_set_and_add() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("t.level");
        g.set(2.5);
        g.add(1.25);
        assert_eq!(g.get(), 3.75);
        g.inc();
        g.dec();
        g.dec();
        assert_eq!(g.get(), 2.75);
        // Handles alias the same storage.
        assert_eq!(reg.gauge("t.level").get(), 2.75);
    }

    #[test]
    fn histogram_stats_are_exact_buckets_are_log2() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("t.lat");
        for v in [0u64, 1, 2, 3, 900, 1500] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!((s.count, s.sum, s.min, s.max), (6, 2406, 0, 1500));
        assert_eq!(s.mean(), 401.0);
        // 0 → width 0; 1 → 1; 2,3 → 2; 900 → 10; 1500 → 11.
        assert_eq!(s.buckets, vec![(0, 1), (1, 1), (2, 2), (10, 1), (11, 1)]);
        assert_eq!(s.quantile(0.0), 0);
        assert!(s.quantile(0.5) <= 3);
        assert_eq!(s.quantile(1.0), 1500);
    }

    #[test]
    fn histogram_snapshots_merge_by_bucket() {
        let a = Histogram::default();
        let b = Histogram::default();
        let all = Histogram::default();
        for v in [0u64, 3, 900] {
            a.observe(v);
            all.observe(v);
        }
        for v in [2u64, 1500] {
            b.observe(v);
            all.observe(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, all.snapshot());
        // Merging an empty snapshot is a no-op; merging into one adopts it.
        let before = merged.clone();
        merged.merge(&HistogramSnapshot::default());
        assert_eq!(merged, before);
        let mut empty = HistogramSnapshot::default();
        empty.merge(&before);
        assert_eq!(empty, before);
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let s = Histogram::default().snapshot();
        assert_eq!((s.count, s.sum, s.min, s.max), (0, 0, 0, 0));
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.quantile(0.99), 0);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let reg = MetricsRegistry::new();
        reg.counter("b.second").add(2);
        reg.counter("a.first").add(1);
        reg.gauge("z.gauge").set(9.0);
        let snap = reg.snapshot();
        assert_eq!(snap.counters, vec![("a.first".to_string(), 1), ("b.second".to_string(), 2)]);
        assert_eq!(snap.gauges, vec![("z.gauge".to_string(), 9.0)]);
        assert!(snap.histograms.is_empty());
    }

    #[test]
    fn window_histogram_rotates_and_ages_out_slices() {
        let reg = MetricsRegistry::new();
        let w = reg.window_histogram("t.win");
        w.observe_at(0, 100);
        w.observe_at(5, 200);
        // Both seconds inside a 10 s window ending at second 5.
        let s = w.snapshot_window_at(5, 10);
        assert_eq!((s.count, s.sum, s.min, s.max), (2, 300, 100, 200));
        // A 1 s window sees only second 5.
        assert_eq!(w.snapshot_window_at(5, 1).count, 1);
        // Second 64 reuses second 0's slice: the old sample is gone.
        w.observe_at(64, 300);
        let s = w.snapshot_window_at(64, 60);
        assert_eq!((s.count, s.sum), (2, 500));
        // Handles alias the same ring.
        assert_eq!(reg.window_histogram("t.win").snapshot_window_at(64, 60).count, 2);
        assert_eq!(reg.window_histograms().len(), 1);
    }

    #[test]
    fn global_registry_is_a_singleton() {
        let a = MetricsRegistry::global();
        let b = MetricsRegistry::global();
        assert!(std::ptr::eq(a, b));
    }
}

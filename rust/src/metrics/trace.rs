//! Lightweight tracing spans, feature-gated behind `trace`.
//!
//! With the (default) feature **off**, [`span`] compiles to a unit struct
//! construction that the optimizer deletes — no clock read, no allocation,
//! no atomic — so instrumented hot paths (schedule planning, batch
//! sharding, per-image forward passes) pay nothing. With
//! `--features trace`, each span records a [`TraceEvent`] (name, start
//! offset from the first span, duration) into a process-global buffer that
//! [`take_events`] drains.
//!
//! ```
//! use tulip::metrics::{span, take_events, trace_enabled};
//!
//! {
//!     let _guard = span("example.work");
//!     // ... traced work ...
//! } // event recorded here (when the `trace` feature is on)
//!
//! let events = take_events();
//! assert_eq!(trace_enabled(), !events.is_empty());
//! ```

#[cfg(feature = "trace")]
use std::collections::VecDeque;
#[cfg(feature = "trace")]
use std::sync::{Mutex, OnceLock};
#[cfg(feature = "trace")]
use std::time::Instant;

/// Maximum number of span events retained between [`take_events`] drains.
/// Once full, the oldest event is dropped per new record and the drop is
/// counted in the global `trace.dropped` counter, so a long-running serve
/// built with `--features trace` holds at most this many events.
pub const TRACE_EVENT_CAPACITY: usize = 65_536;

/// One completed span: recorded when a [`Span`] guard drops (only with the
/// `trace` feature enabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Static span name, e.g. `"scheduler.plan"` or `"batch.image"`.
    pub name: &'static str,
    /// Start time in microseconds since the process's first span.
    pub start_us: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
}

/// RAII guard returned by [`span`]; records a [`TraceEvent`] on drop when
/// the `trace` feature is enabled, and is a zero-sized no-op otherwise.
#[must_use = "a span measures the scope it is bound in; binding to _ drops it immediately"]
#[derive(Debug)]
pub struct Span {
    #[cfg(feature = "trace")]
    inner: Option<(&'static str, Instant)>,
}

/// Open a tracing span covering the enclosing scope.
///
/// Bind the result to a named guard (`let _guard = span("…");`) so it
/// lives until the end of the scope. See the [module docs](self).
#[inline]
pub fn span(name: &'static str) -> Span {
    #[cfg(feature = "trace")]
    {
        Span { inner: Some((name, Instant::now())) }
    }
    #[cfg(not(feature = "trace"))]
    {
        let _ = name;
        Span {}
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        #[cfg(feature = "trace")]
        if let Some((name, start)) = self.inner.take() {
            record(name, start);
        }
    }
}

/// Whether the `trace` feature was compiled in (spans actually record).
pub const fn trace_enabled() -> bool {
    cfg!(feature = "trace")
}

/// Drain and return every event recorded so far, oldest first (always
/// empty when the `trace` feature is off). The backing store is a ring
/// capped at [`TRACE_EVENT_CAPACITY`]; between drains, overflow discards
/// the oldest events and counts them in `trace.dropped`.
pub fn take_events() -> Vec<TraceEvent> {
    #[cfg(feature = "trace")]
    {
        collector().lock().expect("trace collector poisoned").drain(..).collect()
    }
    #[cfg(not(feature = "trace"))]
    {
        Vec::new()
    }
}

#[cfg(feature = "trace")]
fn collector() -> &'static Mutex<VecDeque<TraceEvent>> {
    static EVENTS: OnceLock<Mutex<VecDeque<TraceEvent>>> = OnceLock::new();
    EVENTS.get_or_init(|| Mutex::new(VecDeque::new()))
}

#[cfg(feature = "trace")]
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

#[cfg(feature = "trace")]
fn record(name: &'static str, start: Instant) {
    let end = Instant::now();
    let event = TraceEvent {
        name,
        start_us: start.saturating_duration_since(epoch()).as_micros() as u64,
        dur_ns: end.saturating_duration_since(start).as_nanos() as u64,
    };
    let mut events = collector().lock().expect("trace collector poisoned");
    if events.len() >= TRACE_EVENT_CAPACITY {
        events.pop_front();
        crate::metrics::MetricsRegistry::global().counter("trace.dropped").inc();
    }
    events.push_back(event);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_guard_is_harmless_and_events_match_feature() {
        {
            let _guard = span("test.span");
            let _nested = span("test.nested");
        }
        let events = take_events();
        if trace_enabled() {
            assert_eq!(events.len(), 2);
            // Inner guard drops first.
            assert_eq!(events[0].name, "test.nested");
            assert_eq!(events[1].name, "test.span");
        } else {
            assert!(events.is_empty(), "no-op spans must record nothing");
            return;
        }
        // Buffer was drained.
        assert!(take_events().is_empty());

        // The store is a capped ring: overflow drops the oldest events and
        // counts them, so long-running traced serves stay bounded.
        let dropped = crate::metrics::MetricsRegistry::global().counter("trace.dropped");
        let dropped_before = dropped.get();
        for _ in 0..TRACE_EVENT_CAPACITY + 10 {
            let _guard = span("test.flood");
        }
        assert_eq!(take_events().len(), TRACE_EVENT_CAPACITY);
        assert!(dropped.get() >= dropped_before + 10);
    }
}

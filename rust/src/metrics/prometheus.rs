//! Prometheus text exposition (format 0.0.4) and a strict in-repo format
//! checker.
//!
//! The renderer turns [`MetricsRegistry`] instruments into the plain-text
//! format Prometheus scrapes, std-only like the rest of the crate:
//!
//! * counters → `tulip_<name>_total` (dots become underscores);
//! * gauges → `tulip_<name>`;
//! * log₂ [`Histogram`](super::Histogram)s → native Prometheus histograms
//!   with cumulative `_bucket{le="2^w-1"}` series plus `_sum`/`_count`;
//! * [`WindowHistogram`](super::WindowHistogram)s → live rolling-quantile
//!   gauges `tulip_<name>_rolling{window="10s",quantile="0.99"}` and a
//!   `_rolling_count` per window.
//!
//! [`render`] merges the global registry with every live model lane's
//! scoped registry (lane samples carry a `model="<lane>"` label), grouping
//! samples by family so each metric name gets exactly one `# TYPE` line —
//! a format requirement the bundled [`check_exposition`] enforces, along
//! with name/label/value grammar and histogram completeness. CI runs the
//! checker against a live scrape via `examples/promcheck.rs`.

use super::registry::{MetricsRegistry, MetricsSnapshot};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Rolling windows rendered for every window histogram, in seconds.
pub const ROLLING_WINDOWS_S: [u64; 2] = [10, 60];

/// Rolling quantiles rendered per window (value, label text).
const ROLLING_QUANTILES: [(f64, &str); 2] = [(0.5, "0.5"), (0.99, "0.99")];

/// Map a dot-separated registry name to a Prometheus metric name:
/// `tulip_` prefix, every character outside `[a-zA-Z0-9_]` replaced by
/// `_` (`"serve.latency_us.total"` → `"tulip_serve_latency_us_total"`).
pub fn metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 6);
    out.push_str("tulip_");
    for c in name.chars() {
        out.push(if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' });
    }
    out
}

/// Escape a label value per the exposition format (`\\`, `\"`, `\n`).
fn label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Format an `f64` sample value (`+Inf`/`-Inf`/`NaN` spellings per spec).
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Join base labels with extras into `{k="v",…}` (empty string when none).
fn label_set(base: &[(&str, &str)], extra: &[(&str, &str)]) -> String {
    let mut pairs: Vec<String> = Vec::with_capacity(base.len() + extra.len());
    for (k, v) in base.iter().chain(extra) {
        pairs.push(format!("{k}=\"{}\"", label_value(v)));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

/// Families under construction: family name → (kind, sample lines). The
/// map groups samples across registries so each family is emitted under a
/// single `# TYPE` line.
type Families = BTreeMap<String, (&'static str, Vec<String>)>;

fn push_sample(fams: &mut Families, family: &str, kind: &'static str, line: String) {
    let entry = fams.entry(family.to_string()).or_insert((kind, Vec::new()));
    entry.1.push(line);
}

/// Render one registry's instruments into `fams`, tagging every sample
/// with `base` labels (empty for the global registry, `model="<lane>"`
/// for a lane's scoped registry).
fn render_registry(fams: &mut Families, reg: &MetricsRegistry, base: &[(&str, &str)]) {
    let MetricsSnapshot { counters, gauges, histograms } = reg.snapshot();
    for (name, v) in &counters {
        let fam = format!("{}_total", metric_name(name));
        let line = format!("{fam}{} {v}", label_set(base, &[]));
        push_sample(fams, &fam, "counter", line);
    }
    for (name, v) in &gauges {
        let fam = metric_name(name);
        let line = format!("{fam}{} {}", label_set(base, &[]), fmt_f64(*v));
        push_sample(fams, &fam, "gauge", line);
    }
    for (name, h) in &histograms {
        let fam = metric_name(name);
        let mut cum = 0u64;
        for &(width, n) in &h.buckets {
            cum += n;
            let le = if width >= 64 { u64::MAX } else { (1u64 << width) - 1 };
            let labels = label_set(base, &[("le", &le.to_string())]);
            push_sample(fams, &fam, "histogram", format!("{fam}_bucket{labels} {cum}"));
        }
        let inf = label_set(base, &[("le", "+Inf")]);
        push_sample(fams, &fam, "histogram", format!("{fam}_bucket{inf} {}", h.count));
        push_sample(fams, &fam, "histogram", format!("{fam}_sum{} {}", label_set(base, &[]), h.sum));
        let count_line = format!("{fam}_count{} {}", label_set(base, &[]), h.count);
        push_sample(fams, &fam, "histogram", count_line);
    }
    for (name, w) in reg.window_histograms() {
        let fam = format!("{}_rolling", metric_name(&name));
        let count_fam = format!("{fam}_count");
        for window in ROLLING_WINDOWS_S {
            let snap = w.snapshot_window(window);
            let win = format!("{window}s");
            for (q, q_label) in ROLLING_QUANTILES {
                let labels = label_set(base, &[("window", &win), ("quantile", q_label)]);
                let line = format!("{fam}{labels} {}", snap.quantile(q));
                push_sample(fams, &fam, "gauge", line);
            }
            let labels = label_set(base, &[("window", &win)]);
            push_sample(fams, &count_fam, "gauge", format!("{count_fam}{labels} {}", snap.count));
        }
    }
}

/// Render the global registry plus every live model lane's scoped registry
/// as one Prometheus text exposition. Lane samples carry `model="<lane>"`;
/// lanes retired by `unload_model` are simply absent from the slice, so
/// their series disappear from the next scrape.
pub fn render(global: &MetricsRegistry, lanes: &[(String, Arc<MetricsRegistry>)]) -> String {
    let mut fams = Families::new();
    render_registry(&mut fams, global, &[]);
    for (lane, reg) in lanes {
        render_registry(&mut fams, reg, &[("model", lane)]);
    }
    let mut out = String::new();
    for (family, (kind, samples)) in &fams {
        out.push_str(&format!("# TYPE {family} {kind}\n"));
        for line in samples {
            out.push_str(line);
            out.push('\n');
        }
    }
    out
}

/// Statistics from a successful [`check_exposition`] pass.
#[derive(Debug, Clone, Default)]
pub struct ExpositionStats {
    /// Number of `# TYPE`-declared metric families.
    pub families: usize,
    /// Number of sample lines.
    pub samples: usize,
    sample_lines: Vec<String>,
}

impl ExpositionStats {
    /// Whether any sample line starts with `prefix` — a metric name,
    /// optionally followed by the start of its label set, e.g.
    /// `tulip_serve_latency_us_total_rolling{model="tiny"`.
    pub fn has_series(&self, prefix: &str) -> bool {
        self.sample_lines.iter().any(|l| l.starts_with(prefix))
    }
}

/// Length of the leading metric-name token (Prometheus name grammar
/// `[a-zA-Z_:][a-zA-Z0-9_:]*`); 0 when the line does not start with one.
fn name_len(line: &str) -> usize {
    let b = line.as_bytes();
    let head = |c: u8| c.is_ascii_alphabetic() || c == b'_' || c == b':';
    if b.is_empty() || !head(b[0]) {
        return 0;
    }
    b.iter().take_while(|&&c| head(c) || c.is_ascii_digit()).count()
}

/// Validate and consume one `{k="v",…}` label set, returning the rest.
fn check_labels(line: &str, rest: &str, ln: usize) -> Result<usize> {
    // rest starts just past '{'; returns the offset just past '}'.
    let b = rest.as_bytes();
    let mut i = 0;
    if b.first() == Some(&b'}') {
        return Ok(i + 1);
    }
    loop {
        let n = name_len(&rest[i..]);
        ensure!(n > 0, "line {ln}: invalid label name in {line:?}");
        i += n;
        ensure!(b.get(i) == Some(&b'='), "line {ln}: expected '=' after label name");
        i += 1;
        ensure!(b.get(i) == Some(&b'"'), "line {ln}: expected '\"' to open label value");
        i += 1;
        loop {
            match b.get(i) {
                None => bail!("line {ln}: unterminated label value"),
                Some(b'"') => {
                    i += 1;
                    break;
                }
                Some(b'\\') => {
                    ensure!(
                        matches!(b.get(i + 1), Some(b'\\' | b'"' | b'n')),
                        "line {ln}: invalid escape in label value"
                    );
                    i += 2;
                }
                Some(_) => i += 1,
            }
        }
        match b.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => return Ok(i + 1),
            _ => bail!("line {ln}: expected ',' or '}}' in label set"),
        }
    }
}

/// Validate one sample line, returning its metric name.
fn check_sample(line: &str, ln: usize) -> Result<String> {
    let n = name_len(line);
    ensure!(n > 0, "line {ln}: sample does not start with a valid metric name: {line:?}");
    let name = line[..n].to_string();
    let mut i = n;
    if line.as_bytes().get(i) == Some(&b'{') {
        i += 1 + check_labels(line, &line[i + 1..], ln)?;
    }
    ensure!(line.as_bytes().get(i) == Some(&b' '), "line {ln}: expected space before value");
    let mut fields = line[i + 1..].split(' ');
    let value = fields.next().unwrap_or("");
    ensure!(value.parse::<f64>().is_ok(), "line {ln}: unparseable sample value {value:?}");
    if let Some(ts) = fields.next() {
        ensure!(ts.parse::<i64>().is_ok(), "line {ln}: unparseable timestamp {ts:?}");
    }
    ensure!(fields.next().is_none(), "line {ln}: trailing fields after value/timestamp");
    Ok(name)
}

/// Strictly validate a Prometheus text exposition: metric-name and label
/// grammar, parseable values, at most one `# TYPE` per family declared
/// before its samples, known TYPE kinds, and — for declared histograms —
/// presence of the `_bucket{le="+Inf"}`, `_sum` and `_count` series.
pub fn check_exposition(text: &str) -> Result<ExpositionStats> {
    ensure!(!text.is_empty(), "empty exposition");
    ensure!(text.ends_with('\n'), "exposition must end with a newline");
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut stats = ExpositionStats::default();
    for (idx, line) in text.lines().enumerate() {
        let ln = idx + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(comment) = line.strip_prefix('#') {
            let comment = comment.trim_start();
            if let Some(decl) = comment.strip_prefix("TYPE ") {
                let mut parts = decl.split(' ');
                let (name, kind) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
                ensure!(
                    name_len(name) == name.len() && !name.is_empty(),
                    "line {ln}: invalid family name in TYPE"
                );
                ensure!(
                    matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped"),
                    "line {ln}: unknown TYPE kind {kind:?}"
                );
                ensure!(parts.next().is_none(), "line {ln}: trailing text after TYPE");
                ensure!(
                    types.insert(name.to_string(), kind.to_string()).is_none(),
                    "line {ln}: duplicate TYPE for family {name:?}"
                );
                ensure!(
                    !stats.sample_lines.iter().any(|l| {
                        let got = &l[..name_len(l)];
                        got == name || got.strip_prefix(name).is_some_and(|rest| {
                            matches!(rest, "_bucket" | "_sum" | "_count" | "_total")
                        })
                    }),
                    "line {ln}: TYPE for {name:?} appears after its samples"
                );
            }
            // `# HELP …` and plain comments are fine as-is.
            continue;
        }
        check_sample(line, ln)?;
        stats.sample_lines.push(line.to_string());
        stats.samples += 1;
    }
    stats.families = types.len();
    for (name, kind) in &types {
        if kind == "histogram" {
            for suffix in ["_bucket{", "_sum", "_count"] {
                let want = format!("{name}{suffix}");
                ensure!(
                    stats.has_series(&want),
                    "histogram family {name:?} is missing its {suffix} series"
                );
            }
            let inf = "le=\"+Inf\"";
            ensure!(
                stats
                    .sample_lines
                    .iter()
                    .any(|l| l.starts_with(&format!("{name}_bucket{{")) && l.contains(inf)),
                "histogram family {name:?} has no le=\"+Inf\" bucket"
            );
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_passes_checker_and_names_are_sanitized() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.admitted").add(5);
        reg.gauge("batch.energy_per_classification_pj").set(12.5);
        let h = reg.histogram("serve.latency_us.total");
        h.observe(0);
        h.observe(900);
        reg.window_histogram("serve.latency_us.total").observe(900);
        let text = render(&reg, &[]);
        let stats = check_exposition(&text).unwrap();
        assert!(stats.has_series("tulip_serve_admitted_total 5"), "{text}");
        assert!(stats.has_series("tulip_batch_energy_per_classification_pj 12.5"), "{text}");
        assert!(stats.has_series("tulip_serve_latency_us_total_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(stats.has_series("tulip_serve_latency_us_total_rolling{window=\"10s\""), "{text}");
        assert!(stats.has_series("tulip_serve_latency_us_total_rolling_count{window=\"60s\""));
    }

    #[test]
    fn lane_registries_are_labeled_and_disappear_when_dropped() {
        let global = MetricsRegistry::new();
        let lane = Arc::new(MetricsRegistry::new());
        lane.counter("serve.completed").add(3);
        let lanes = vec![("tiny".to_string(), Arc::clone(&lane))];
        let text = render(&global, &lanes);
        check_exposition(&text).unwrap();
        assert!(text.contains("tulip_serve_completed_total{model=\"tiny\"} 3"), "{text}");
        // A retired lane is simply absent from the next render.
        let text = render(&global, &[]);
        assert!(!text.contains("model=\"tiny\""), "{text}");
    }

    #[test]
    fn histogram_buckets_are_cumulative_with_inf() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("t.lat");
        for v in [0u64, 1, 2, 3, 900] {
            h.observe(v);
        }
        let text = render(&reg, &[]);
        check_exposition(&text).unwrap();
        assert!(text.contains("tulip_t_lat_bucket{le=\"0\"} 1\n"), "{text}");
        assert!(text.contains("tulip_t_lat_bucket{le=\"1\"} 2\n"), "{text}");
        assert!(text.contains("tulip_t_lat_bucket{le=\"3\"} 4\n"), "{text}");
        assert!(text.contains("tulip_t_lat_bucket{le=\"1023\"} 5\n"), "{text}");
        assert!(text.contains("tulip_t_lat_bucket{le=\"+Inf\"} 5\n"), "{text}");
        assert!(text.contains("tulip_t_lat_sum 906\n"), "{text}");
        assert!(text.contains("tulip_t_lat_count 5\n"), "{text}");
    }

    #[test]
    fn checker_rejects_malformed_expositions() {
        assert!(check_exposition("").is_err(), "empty");
        assert!(check_exposition("tulip_ok 1").is_err(), "missing trailing newline");
        assert!(check_exposition("9bad_name 1\n").is_err(), "name starts with digit");
        assert!(check_exposition("tulip_ok notanumber\n").is_err(), "bad value");
        assert!(check_exposition("tulip_ok{le=\"unterminated} 1\n").is_err(), "bad label");
        assert!(check_exposition("tulip_ok{le=+Inf} 1\n").is_err(), "unquoted label value");
        assert!(
            check_exposition("# TYPE tulip_x counter\n# TYPE tulip_x counter\ntulip_x 1\n")
                .is_err(),
            "duplicate TYPE"
        );
        assert!(
            check_exposition("tulip_x_total 1\n# TYPE tulip_x_total counter\n").is_err(),
            "TYPE after samples"
        );
        assert!(
            check_exposition("# TYPE tulip_h histogram\ntulip_h_sum 1\ntulip_h_count 1\n")
                .is_err(),
            "histogram without +Inf bucket"
        );
        // Valid: comments, HELP, timestamps, NaN/Inf values, escapes.
        let ok = "# scraped from tulip\n# HELP tulip_g a gauge\n# TYPE tulip_g gauge\n\
                  tulip_g{model=\"a\\\\b\\\"c\\nd\"} NaN 1700000000\ntulip_g2 +Inf\n";
        let stats = check_exposition(ok).unwrap();
        assert_eq!((stats.families, stats.samples), (1, 2));
    }
}

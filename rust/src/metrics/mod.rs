//! Observability: a structured metrics [`registry`], feature-gated tracing
//! [spans](trace), and the paper-format [table printers](tables).
//!
//! Three layers, coarsest to finest:
//!
//! 1. **Tables** ([`tables`]) — human-readable reproductions of the
//!    paper's Tables I–V and Fig. 7, printed by the CLI.
//! 2. **Registry** ([`registry`]) — thread-safe counters, gauges and
//!    histograms that the batch executor, schedule cache, PE simulator
//!    and energy model report into ([`MetricsRegistry::global`] by
//!    default). Snapshots are deterministic and serialize into
//!    [`PerfReport`](crate::coordinator::PerfReport) JSON.
//! 3. **Spans** ([`trace`]) — RAII timing guards around schedule
//!    planning, batch sharding and per-image forward passes. Compiled
//!    out entirely (zero cost) unless the crate is built with
//!    `--features trace`.

pub mod registry;
pub mod tables;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, MetricsSnapshot};
pub use tables::{print_comparison, print_fig7, print_table1, print_table2, print_table3};
pub use trace::{span, take_events, trace_enabled, Span, TraceEvent};

//! Observability: a structured metrics [`registry`], live [`prometheus`]
//! exposition, the per-request [`flight`] recorder, feature-gated tracing
//! [spans](trace), and the paper-format [table printers](tables).
//!
//! Layers, coarsest to finest:
//!
//! 1. **Tables** ([`tables`]) — human-readable reproductions of the
//!    paper's Tables I–V and Fig. 7, printed by the CLI.
//! 2. **Registry** ([`registry`]) — thread-safe counters, gauges,
//!    histograms and sliding-window histograms that the batch executor,
//!    schedule cache, PE simulator and energy model report into
//!    ([`MetricsRegistry::global`] by default). Snapshots are
//!    deterministic and serialize into
//!    [`PerfReport`](crate::coordinator::PerfReport) JSON.
//! 3. **Exposition** ([`prometheus`]) — renders every registry (global
//!    plus per-model lanes) in Prometheus text format for the serving
//!    stack's `--metrics-addr` endpoint, and bundles the strict format
//!    checker CI scrapes with.
//! 4. **Flight recorder** ([`flight`]) — an always-on, lock-free ring of
//!    per-request span events (admit → dequeue → batch-seal → execute →
//!    respond), dumpable as `tulip.trace/v1` JSON and convertible to
//!    Chrome `trace_event` JSON.
//! 5. **Spans** ([`trace`]) — RAII timing guards around schedule
//!    planning, batch sharding and per-image forward passes. Compiled
//!    out entirely (zero cost) unless the crate is built with
//!    `--features trace`.

pub mod flight;
pub mod prometheus;
pub mod registry;
pub mod tables;
pub mod trace;

pub use flight::{FlightDump, FlightEvent, FlightRecorder, FlightStage};
pub use prometheus::{check_exposition, ExpositionStats};
pub use registry::{
    Counter, Gauge, Histogram, HistogramSnapshot, MetricsRegistry, MetricsSnapshot,
    WindowHistogram,
};
pub use tables::{print_comparison, print_fig7, print_table1, print_table2, print_table3};
pub use trace::{span, take_events, trace_enabled, Span, TraceEvent};

//! Paper-format table rendering shared by the CLI, examples and benches —
//! reproductions of the paper's Tables I–V and the Fig. 7 area rollup.

use crate::bnn::Network;
use crate::coordinator::report::{Comparison, Table2};
use crate::coordinator::table3;
use crate::energy::{tulip_area, yodann_area};
use crate::neuron::{table1_improvements, Corner, CMOS_EQUIVALENT, HW_NEURON};
use crate::util::bench::print_table;

/// Print Table I (hardware neuron vs CMOS standard-cell equivalent).
pub fn print_table1() {
    let (a, p, d) = table1_improvements();
    print_table(
        "Table I: Hardware neuron versus standard cell neuron (TT corner)",
        &["", "Hardware Neuron [21]", "Logical Equivalent", "X Improve"],
        &[
            vec![
                "Area (um^2)".into(),
                format!("{:.1}", HW_NEURON.area_um2),
                format!("{:.1}", CMOS_EQUIVALENT.area_um2),
                format!("{:.1}X", a),
            ],
            vec![
                "Power (uW)".into(),
                format!("{:.2}", HW_NEURON.power_uw),
                format!("{:.2}", CMOS_EQUIVALENT.power_uw),
                format!("{:.1}X", p),
            ],
            vec![
                "Worst Delay (ps)".into(),
                format!("{:.0}", HW_NEURON.worst_delay_ps),
                format!("{:.0}", CMOS_EQUIVALENT.worst_delay_ps),
                format!("{:.1}X", d),
            ],
        ],
    );
    // Corner characterization (§V-A: SS 0.81V 125C, TT 0.9V 25C, FF 0.99V 0C).
    let rows: Vec<Vec<String>> = Corner::ALL
        .iter()
        .map(|&c| {
            let h = HW_NEURON.at_corner(c);
            vec![
                c.to_string(),
                format!("{:.2}", h.power_uw),
                format!("{:.0}", h.worst_delay_ps),
            ]
        })
        .collect();
    print_table("Hardware neuron across corners", &["corner", "power (uW)", "delay (ps)"], &rows);
}

/// Print Table II (MAC vs TULIP-PE for the 288-input neuron).
pub fn print_table2() -> Table2 {
    let t = Table2::compute();
    print_table(
        "Table II: fully reconfigurable MAC [17] vs TULIP-PE, 288-input neuron (3x3, 32 IFMs)",
        &["Single PE Metrics", "YodaNN MAC (B)", "TULIP-PE (T)", "Ratio (B/T)"],
        &t.rows(),
    );
    println!("power-delay-product advantage (paper: 2.27X): {:.2}X", t.pdp_ratio());
    t
}

/// Print Table III (P / Z / P×Z per conv layer).
pub fn print_table3(net: &Network) {
    let rows: Vec<Vec<String>> = table3(net)
        .iter()
        .map(|r| {
            vec![
                format!("{} ({})", r.layer, r.kind),
                r.parts.to_string(),
                r.yodann.p.to_string(),
                r.yodann.z.to_string(),
                r.yodann.refetch_pressure().to_string(),
                r.tulip.p.to_string(),
                r.tulip.z.to_string(),
                r.tulip.refetch_pressure().to_string(),
            ]
        })
        .collect();
    print_table(
        &format!("Table III: input fetch requirements, {} layers", net.name),
        &["Layer", "Parts", "Y.P", "Y.Z", "Y.P*Z", "T.P", "T.Z", "T.P*Z"],
        &rows,
    );
}

/// Print a Table IV/V-style comparison for a network.
pub fn print_comparison(net: &Network, conv_only: bool) -> Comparison {
    let c = Comparison::run(net, conv_only);
    let scope = if conv_only { "Conv only (Table IV)" } else { "All layers (Table V)" };
    print_table(
        &format!("{scope}: {} / {}", c.network, c.dataset),
        &["", "YodaNN", "TULIP (X)"],
        &c.rows(),
    );
    c
}

/// Print the Fig. 7 area rollup for both designs.
pub fn print_fig7() {
    let t = tulip_area();
    let y = yodann_area();
    print_table(
        "Fig. 7: area rollup (um^2)",
        &["component", "TULIP", "YodaNN"],
        &[
            vec![
                "processing (PEs+MACs)".into(),
                format!("{:.0}", t.processing_um2),
                format!("{:.0}", y.processing_um2),
            ],
            vec![
                "image buffer (L1+L2)".into(),
                format!("{:.0}", t.image_buffer_um2),
                format!("{:.0}", y.image_buffer_um2),
            ],
            vec![
                "kernel buffer".into(),
                format!("{:.0}", t.kernel_buffer_um2),
                format!("{:.0}", y.kernel_buffer_um2),
            ],
            vec![
                "controller".into(),
                format!("{:.0}", t.controller_um2),
                format!("{:.0}", y.controller_um2),
            ],
            vec![
                "total (mm^2)".into(),
                format!("{:.2}", t.total_mm2()),
                format!("{:.2}", y.total_mm2()),
            ],
        ],
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::binarynet_cifar10;

    #[test]
    fn printers_do_not_panic() {
        print_table1();
        let t2 = print_table2();
        assert!(t2.pe_cycles > 0);
        let net = binarynet_cifar10();
        print_table3(&net);
        let c = print_comparison(&net, true);
        assert!(c.efficiency_gain() > 1.0);
        print_fig7();
    }
}

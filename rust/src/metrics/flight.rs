//! The flight recorder: an always-on, fixed-capacity, lock-free ring of
//! per-request span events for the serving stack.
//!
//! Every admitted request is issued a process-unique flight id at the
//! admission queue; the id rides the request through queue → batcher →
//! engine, and each hop records one [`FlightEvent`]
//! (admit/dequeue/shed/batch-seal/execute/respond, tagged with the lane
//! and — once sealed — the micro-batch id) into the global
//! [`FlightRecorder`]. Unlike the feature-gated spans in
//! [`trace`](crate::metrics::trace), the recorder is compiled in
//! unconditionally: writers touch a handful of relaxed atomics per event,
//! so an operator can always ask a live server what happened to a slow
//! request.
//!
//! Dumps serialize as `tulip.trace/v1` JSON (one line, served by the
//! `{"op": "trace_dump"}` wire op and the `/trace` telemetry endpoint) and
//! convert to Chrome `trace_event` JSON for `chrome://tracing` via
//! [`FlightDump::chrome_trace`].
//!
//! ```
//! use tulip::metrics::flight::{FlightRecorder, FlightStage};
//!
//! let rec = FlightRecorder::with_capacity(8);
//! let lane = tulip::metrics::flight::lane_id("doc-lane");
//! rec.record(FlightStage::Admit, 1, 7, lane, 0);
//! rec.record(FlightStage::Respond, 1, 7, lane, 3);
//! let dump = rec.snapshot();
//! assert_eq!(dump.events.len(), 2);
//! assert_eq!(dump.dropped, 0);
//! ```

use crate::serve::protocol::{json_str, parse_json, Json};
use anyhow::{ensure, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Capacity of the process-global [`recorder`]: at ~6 events per request
/// this retains the last ~10 k requests, and the ring costs ~3.5 MiB.
pub const FLIGHT_CAPACITY: usize = 65_536;

/// A request's position in its lifecycle when an event was recorded.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FlightStage {
    /// Accepted by the admission queue; the flight id is assigned here.
    Admit,
    /// Pulled off the queue by the batcher.
    Dequeue,
    /// Deadline expired while queued — replied `shed`, never executed.
    Shed,
    /// Survived shedding and sealed into a micro-batch (batch id assigned).
    BatchSeal,
    /// The micro-batch finished on the engine.
    Execute,
    /// The response left the batcher toward the client connection.
    Respond,
}

impl FlightStage {
    /// Wire name (`tulip.trace/v1` `stage` field).
    pub fn name(self) -> &'static str {
        match self {
            FlightStage::Admit => "admit",
            FlightStage::Dequeue => "dequeue",
            FlightStage::Shed => "shed",
            FlightStage::BatchSeal => "batch_seal",
            FlightStage::Execute => "execute",
            FlightStage::Respond => "respond",
        }
    }

    /// Parse a wire name.
    pub fn from_name(s: &str) -> Option<FlightStage> {
        match s {
            "admit" => Some(FlightStage::Admit),
            "dequeue" => Some(FlightStage::Dequeue),
            "shed" => Some(FlightStage::Shed),
            "batch_seal" => Some(FlightStage::BatchSeal),
            "execute" => Some(FlightStage::Execute),
            "respond" => Some(FlightStage::Respond),
            _ => None,
        }
    }

    fn from_code(c: u64) -> Option<FlightStage> {
        [
            FlightStage::Admit,
            FlightStage::Dequeue,
            FlightStage::Shed,
            FlightStage::BatchSeal,
            FlightStage::Execute,
            FlightStage::Respond,
        ]
        .into_iter()
        .find(|s| *s as u64 == c)
    }
}

/// One recorded hop of one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightEvent {
    /// Microseconds since the recorder's epoch (its construction).
    pub ts_us: u64,
    /// Process-unique flight id assigned at admission.
    pub flight: u64,
    /// The client-chosen request id (echoed on the wire response).
    pub request: u64,
    /// Interned lane id — resolve with [`lane_name`].
    pub lane: u64,
    /// Micro-batch id (0 until [`FlightStage::BatchSeal`]).
    pub batch: u64,
    /// Lifecycle stage.
    pub stage: FlightStage,
}

/// Sentinel sequence marking a slot mid-write (readers skip it).
const WRITING: u64 = u64::MAX;

/// One ring slot: a seqlock over the event fields. Writers claim a slot by
/// bumping the ring head, mark it [`WRITING`], store the fields with
/// relaxed stores, then publish the claim ticket; readers re-check the
/// sequence after loading the fields and discard torn reads.
#[derive(Debug)]
struct Slot {
    seq: AtomicU64,
    ts_us: AtomicU64,
    flight: AtomicU64,
    request: AtomicU64,
    lane: AtomicU64,
    batch: AtomicU64,
    stage: AtomicU64,
}

impl Slot {
    fn new() -> Self {
        Slot {
            seq: AtomicU64::new(0),
            ts_us: AtomicU64::new(0),
            flight: AtomicU64::new(0),
            request: AtomicU64::new(0),
            lane: AtomicU64::new(0),
            batch: AtomicU64::new(0),
            stage: AtomicU64::new(0),
        }
    }
}

/// Fixed-capacity, lock-free ring of [`FlightEvent`]s (see the
/// [module docs](self)). Writers never block and never allocate; once the
/// ring wraps, the oldest events are overwritten and counted as dropped.
#[derive(Debug)]
pub struct FlightRecorder {
    epoch: Instant,
    slots: Box<[Slot]>,
    head: AtomicU64,
}

impl FlightRecorder {
    /// A recorder holding the most recent `capacity` events (min 1).
    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        FlightRecorder {
            epoch: Instant::now(),
            slots: (0..capacity).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    /// Number of events the ring retains.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Record one event at the current time.
    pub fn record(&self, stage: FlightStage, flight: u64, request: u64, lane: u64, batch: u64) {
        let ts_us = self.epoch.elapsed().as_micros() as u64;
        let ticket = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        slot.seq.store(WRITING, Ordering::Release);
        slot.ts_us.store(ts_us, Ordering::Relaxed);
        slot.flight.store(flight, Ordering::Relaxed);
        slot.request.store(request, Ordering::Relaxed);
        slot.lane.store(lane, Ordering::Relaxed);
        slot.batch.store(batch, Ordering::Relaxed);
        slot.stage.store(stage as u64, Ordering::Relaxed);
        // Publish: tickets start at 0, so the stored sequence is ticket+1
        // and 0 still means "never written".
        slot.seq.store(ticket + 1, Ordering::Release);
    }

    /// Copy out every readable event, oldest first. Slots being written
    /// while the copy runs (torn reads) are skipped — under load the dump
    /// loses at most as many events as there are concurrent writers.
    pub fn snapshot(&self) -> FlightDump {
        let head = self.head.load(Ordering::Acquire);
        let mut tagged: Vec<(u64, FlightEvent)> = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == 0 || seq == WRITING {
                continue;
            }
            let ev = FlightEvent {
                ts_us: slot.ts_us.load(Ordering::Relaxed),
                flight: slot.flight.load(Ordering::Relaxed),
                request: slot.request.load(Ordering::Relaxed),
                lane: slot.lane.load(Ordering::Relaxed),
                batch: slot.batch.load(Ordering::Relaxed),
                stage: match FlightStage::from_code(slot.stage.load(Ordering::Relaxed)) {
                    Some(s) => s,
                    None => continue, // torn read caught a half-written slot
                },
            };
            if slot.seq.load(Ordering::Acquire) != seq {
                continue; // a writer reclaimed the slot mid-copy
            }
            tagged.push((seq, ev));
        }
        tagged.sort_unstable_by_key(|&(seq, _)| seq);
        FlightDump {
            capacity: self.slots.len(),
            dropped: head.saturating_sub(self.slots.len() as u64),
            events: tagged.into_iter().map(|(_, ev)| ev).collect(),
        }
    }
}

/// The process-global recorder every serve lane records into.
pub fn recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(|| FlightRecorder::with_capacity(FLIGHT_CAPACITY))
}

/// Issue the next process-unique flight id (1-based; 0 = unassigned).
pub fn next_flight_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Issue the next process-unique micro-batch id (1-based; 0 = unsealed).
pub fn next_batch_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

fn lanes() -> &'static Mutex<Vec<String>> {
    static LANES: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    LANES.get_or_init(|| Mutex::new(Vec::new()))
}

/// Intern a lane name, returning its stable integer id. Events store the
/// id so the hot path never copies strings; names intern at most once per
/// lane load, so the table stays as small as the set of distinct names.
pub fn lane_id(name: &str) -> u64 {
    let mut table = lanes().lock().expect("flight lane table poisoned");
    if let Some(i) = table.iter().position(|n| n == name) {
        return i as u64;
    }
    table.push(name.to_string());
    (table.len() - 1) as u64
}

/// Resolve an interned lane id back to its name.
pub fn lane_name(id: u64) -> Option<String> {
    lanes().lock().expect("flight lane table poisoned").get(id as usize).cloned()
}

/// A frozen copy of the recorder: what `{"op": "trace_dump"}`, the
/// `/trace` endpoint and `tulip trace-dump` serve.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FlightDump {
    /// Events oldest-first (by record order).
    pub events: Vec<FlightEvent>,
    /// Events overwritten by ring wrap-around before this dump.
    pub dropped: u64,
    /// Ring capacity of the recorder that produced the dump.
    pub capacity: usize,
}

impl FlightDump {
    /// Encode as one `tulip.trace/v1` JSON line (no trailing newline).
    /// Lane ids serialize as their interned names.
    pub fn to_json_line(&self) -> String {
        let events: Vec<String> = self
            .events
            .iter()
            .map(|e| {
                let lane = lane_name(e.lane).unwrap_or_else(|| format!("lane{}", e.lane));
                format!(
                    "{{\"ts_us\": {}, \"flight\": {}, \"request\": {}, \"lane\": {}, \
                     \"batch\": {}, \"stage\": {}}}",
                    e.ts_us,
                    e.flight,
                    e.request,
                    json_str(&lane),
                    e.batch,
                    json_str(e.stage.name())
                )
            })
            .collect();
        format!(
            "{{\"schema\": \"tulip.trace/v1\", \"capacity\": {}, \"dropped\": {}, \
             \"events\": [{}]}}",
            self.capacity,
            self.dropped,
            events.join(", ")
        )
    }

    /// Decode a `tulip.trace/v1` line (clients and tests; lane names
    /// re-intern in the reading process).
    pub fn parse(line: &str) -> Result<FlightDump> {
        let v = parse_json(line).context("malformed trace dump")?;
        let schema = v.get("schema").and_then(Json::as_str).unwrap_or("");
        ensure!(schema == "tulip.trace/v1", "unsupported trace schema '{schema}'");
        let capacity = v.get("capacity").and_then(Json::as_u64).unwrap_or(0) as usize;
        let dropped = v.get("dropped").and_then(Json::as_u64).unwrap_or(0);
        let mut events = Vec::new();
        if let Some(Json::Arr(items)) = v.get("events") {
            for item in items {
                let field = |k: &str| item.get(k).and_then(Json::as_u64);
                let stage = item
                    .get("stage")
                    .and_then(Json::as_str)
                    .and_then(FlightStage::from_name)
                    .context("event with missing/unknown 'stage'")?;
                let lane = item.get("lane").and_then(Json::as_str).unwrap_or("");
                events.push(FlightEvent {
                    ts_us: field("ts_us").context("event missing 'ts_us'")?,
                    flight: field("flight").context("event missing 'flight'")?,
                    request: field("request").unwrap_or(0),
                    lane: lane_id(lane),
                    batch: field("batch").unwrap_or(0),
                    stage,
                });
            }
        }
        Ok(FlightDump { events, dropped, capacity })
    }

    /// Convert to Chrome `trace_event` JSON (the object form,
    /// `{"traceEvents": [...]}`), loadable in `chrome://tracing` or Perfetto.
    ///
    /// Each lane becomes a process (named via `process_name` metadata) and
    /// each flight a thread within it; adjacent stage pairs become `"X"`
    /// complete events (`queued` = admit→dequeue, `execute` =
    /// dequeue→execute, `respond` = execute→respond) and sheds become
    /// instant events.
    pub fn chrome_trace(&self) -> String {
        let mut out: Vec<String> = Vec::new();
        let mut lanes_seen: Vec<u64> = Vec::new();
        for e in &self.events {
            if !lanes_seen.contains(&e.lane) {
                lanes_seen.push(e.lane);
                let name = lane_name(e.lane).unwrap_or_else(|| format!("lane{}", e.lane));
                out.push(format!(
                    "{{\"ph\": \"M\", \"pid\": {}, \"name\": \"process_name\", \
                     \"args\": {{\"name\": {}}}}}",
                    e.lane,
                    json_str(&format!("lane {name}"))
                ));
            }
        }
        // Group events per flight, preserving record order within a flight.
        let mut flights: Vec<(u64, Vec<&FlightEvent>)> = Vec::new();
        for e in &self.events {
            match flights.iter_mut().find(|(f, _)| *f == e.flight) {
                Some((_, evs)) => evs.push(e),
                None => flights.push((e.flight, vec![e])),
            }
        }
        for (flight, evs) in &flights {
            let at = |stage: FlightStage| evs.iter().find(|e| e.stage == stage);
            let batch = evs.iter().map(|e| e.batch).max().unwrap_or(0);
            let request = evs.first().map(|e| e.request).unwrap_or(0);
            let lane = evs.first().map(|e| e.lane).unwrap_or(0);
            let spans = [
                ("queued", FlightStage::Admit, FlightStage::Dequeue),
                ("execute", FlightStage::Dequeue, FlightStage::Execute),
                ("respond", FlightStage::Execute, FlightStage::Respond),
            ];
            for (name, from, to) in spans {
                if let (Some(a), Some(b)) = (at(from), at(to)) {
                    out.push(format!(
                        "{{\"ph\": \"X\", \"pid\": {lane}, \"tid\": {flight}, \
                         \"name\": {}, \"ts\": {}, \"dur\": {}, \
                         \"args\": {{\"request\": {request}, \"batch\": {batch}}}}}",
                        json_str(name),
                        a.ts_us,
                        b.ts_us.saturating_sub(a.ts_us)
                    ));
                }
            }
            if let Some(s) = at(FlightStage::Shed) {
                out.push(format!(
                    "{{\"ph\": \"i\", \"pid\": {lane}, \"tid\": {flight}, \
                     \"name\": \"shed\", \"ts\": {}, \"s\": \"t\", \
                     \"args\": {{\"request\": {request}}}}}",
                    s.ts_us
                ));
            }
        }
        format!("{{\"traceEvents\": [{}]}}", out.join(", "))
    }

    /// The stages recorded for one client request id, in record order
    /// (dump-verification helper for clients).
    pub fn stages_for_request(&self, request: u64) -> Vec<FlightStage> {
        self.events.iter().filter(|e| e.request == request).map(|e| e.stage).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_drops() {
        let rec = FlightRecorder::with_capacity(8);
        let lane = lane_id("t.lane");
        for i in 0..20u64 {
            rec.record(FlightStage::Admit, i + 1, i, lane, 0);
        }
        let dump = rec.snapshot();
        assert_eq!(dump.events.len(), 8);
        assert_eq!(dump.dropped, 12);
        assert_eq!(dump.capacity, 8);
        // Oldest-first: the surviving events are the last 8 recorded.
        let flights: Vec<u64> = dump.events.iter().map(|e| e.flight).collect();
        assert_eq!(flights, (13..=20).collect::<Vec<u64>>());
    }

    #[test]
    fn dump_json_round_trips() {
        let rec = FlightRecorder::with_capacity(16);
        let lane = lane_id("t.round");
        rec.record(FlightStage::Admit, 5, 99, lane, 0);
        rec.record(FlightStage::Dequeue, 5, 99, lane, 0);
        rec.record(FlightStage::BatchSeal, 5, 99, lane, 2);
        rec.record(FlightStage::Execute, 5, 99, lane, 2);
        rec.record(FlightStage::Respond, 5, 99, lane, 2);
        let dump = rec.snapshot();
        let back = FlightDump::parse(&dump.to_json_line()).unwrap();
        assert_eq!(back, dump);
        assert_eq!(back.stages_for_request(99), vec![
            FlightStage::Admit,
            FlightStage::Dequeue,
            FlightStage::BatchSeal,
            FlightStage::Execute,
            FlightStage::Respond
        ]);
        assert!(FlightDump::parse("{\"schema\": \"nope\"}").is_err());
    }

    #[test]
    fn chrome_trace_is_valid_json_with_spans() {
        let rec = FlightRecorder::with_capacity(16);
        let lane = lane_id("t.chrome");
        rec.record(FlightStage::Admit, 7, 1, lane, 0);
        rec.record(FlightStage::Dequeue, 7, 1, lane, 0);
        rec.record(FlightStage::BatchSeal, 7, 1, lane, 4);
        rec.record(FlightStage::Execute, 7, 1, lane, 4);
        rec.record(FlightStage::Respond, 7, 1, lane, 4);
        rec.record(FlightStage::Admit, 8, 2, lane, 0);
        rec.record(FlightStage::Shed, 8, 2, lane, 0);
        let chrome = rec.snapshot().chrome_trace();
        let v = parse_json(&chrome).unwrap();
        let events = match v.get("traceEvents") {
            Some(Json::Arr(items)) => items.clone(),
            other => panic!("expected traceEvents array, got {other:?}"),
        };
        // 1 process_name metadata + 3 spans for flight 7 + 1 shed instant.
        assert_eq!(events.len(), 5);
        let phases: Vec<&str> =
            events.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "i").count(), 1);
    }

    #[test]
    fn lane_interning_is_stable() {
        let a = lane_id("t.intern.a");
        let b = lane_id("t.intern.b");
        assert_ne!(a, b);
        assert_eq!(lane_id("t.intern.a"), a);
        assert_eq!(lane_name(a).as_deref(), Some("t.intern.a"));
        assert_eq!(lane_name(u64::MAX), None);
    }
}

//! `tulip` — CLI for the TULIP reproduction.
//!
//! Subcommands (std-only argument parsing; clap is unavailable in the
//! offline vendor set):
//!
//! ```text
//! tulip tables [--network binarynet|alexnet]   # Tables I–V + Fig. 7
//! tulip table <1|2|3|4|5|fig7>                 # one paper artifact
//! tulip simulate [--network ...] [--arch tulip|yodann] [--pes N]
//! tulip schedule <fanin> [threshold]           # RPO schedule stats
//! tulip golden <artifact-stem>                 # load + run a golden model
//! tulip model export --model <name> [--seed N] [--out PATH]
//! tulip model inspect <PATH>                   # tulip.model/v1 artifacts
//! tulip serve [--addr H:P] [--model NAME | --model NAME=PATH]...
//!             [--max-batch N] [--max-wait-us N] [--queue-cap N]
//!             [--policy block|reject] [--engine scalar|bit_sliced]
//!             [--perf-out PATH] [--metrics-addr H:P]  # TCP inference front-end
//! tulip trace-dump [--addr H:P] [--out PATH] [--chrome PATH]
//! ```
//!
//! `serve` takes `--model` repeatedly; each is either a built-in demo name
//! (`tiny`, `tiny8`) or `name=path` pointing at a `tulip.model/v1` file
//! (as written by `tulip model export`). The first model is the default
//! route for requests that omit the `model` field. `--metrics-addr` opens
//! the live-telemetry HTTP endpoint (`/metrics`, `/healthz`, `/readyz`,
//! `/trace`); `trace-dump` pulls the flight recorder from a running server
//! over the wire protocol and can convert it to Chrome `trace_event` JSON.

use tulip::bnn::{alexnet, binarynet_cifar10, Model, Network};
use tulip::config::ArchConfig;
use tulip::coordinator::NetworkPerf;
use tulip::metrics;
use tulip::scheduler::adder_tree;

fn usage() -> ! {
    eprintln!(
        "usage: tulip <tables|table|simulate|schedule|golden|model|serve|trace-dump> [args]\n\
         \n  tulip tables [--network binarynet|alexnet]\
         \n  tulip table <1|2|3|4|5|fig7> [--network ...]\
         \n  tulip simulate [--network ...] [--arch tulip|yodann] [--pes N]\
         \n  tulip schedule <fanin> [threshold]\
         \n  tulip golden <artifact-stem>\
         \n  tulip model export --model <tiny|tiny8|binarynet|alexnet> [--seed N] [--out PATH]\
         \n  tulip model inspect <PATH>\
         \n  tulip serve [--addr 127.0.0.1:7070] [--model NAME | --model NAME=PATH]...\
         \n              [--max-batch 64] [--max-wait-us 2000] [--queue-cap 1024]\
         \n              [--policy block|reject] [--engine scalar|bit_sliced]\
         \n              [--perf-out PATH] [--metrics-addr 127.0.0.1:9091]\
         \n  tulip trace-dump [--addr 127.0.0.1:7070] [--out trace.json] [--chrome PATH]"
    );
    std::process::exit(2);
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

/// Every value of a repeatable flag, in order (`--model a --model b=c.json`).
fn flag_values(args: &[String], flag: &str) -> Vec<String> {
    args.iter()
        .enumerate()
        .filter(|(_, a)| *a == flag)
        .filter_map(|(i, _)| args.get(i + 1).cloned())
        .collect()
}

/// Resolve one `--model` spec: a demo name (`tiny`, `tiny8`), a
/// `tulip.model/v1` file path, or `name=path`.
fn resolve_model(spec: &str) -> Result<(String, Model), String> {
    if let Some((name, path)) = spec.split_once('=') {
        let model = Model::load(path).map_err(|e| format!("{e}"))?;
        return Ok((name.to_string(), model));
    }
    if let Some(model) = Model::demo(spec) {
        return Ok((spec.to_string(), model));
    }
    if spec.ends_with(".json") {
        let model = Model::load(spec).map_err(|e| format!("{e}"))?;
        let name = model.name().to_string();
        return Ok((name, model));
    }
    Err(format!("unknown model '{spec}' (tiny|tiny8, a .json path, or name=path)"))
}

fn pick_network(args: &[String]) -> Network {
    match flag_value(args, "--network").as_deref() {
        Some("alexnet") => alexnet(),
        Some("binarynet") | None => binarynet_cifar10(),
        Some(other) => {
            eprintln!("unknown network '{other}' (binarynet|alexnet)");
            std::process::exit(2);
        }
    }
}

fn cmd_tables(args: &[String]) {
    let net = pick_network(args);
    metrics::print_table1();
    metrics::print_table2();
    metrics::print_table3(&tulip::bnn::alexnet());
    metrics::print_comparison(&net, true);
    metrics::print_comparison(&net, false);
    metrics::print_fig7();
}

fn cmd_table(args: &[String]) {
    let which = args.first().map(String::as_str).unwrap_or("");
    let net = pick_network(args);
    match which {
        "1" => metrics::print_table1(),
        "2" => {
            metrics::print_table2();
        }
        "3" => metrics::print_table3(&tulip::bnn::alexnet()),
        "4" => {
            metrics::print_comparison(&net, true);
        }
        "5" => {
            metrics::print_comparison(&net, false);
        }
        "fig7" => metrics::print_fig7(),
        _ => usage(),
    }
}

fn cmd_simulate(args: &[String]) {
    let net = pick_network(args);
    let mut cfg = match flag_value(args, "--arch").as_deref() {
        Some("yodann") => ArchConfig::yodann(),
        _ => ArchConfig::tulip(),
    };
    if let Some(p) = flag_value(args, "--pes").and_then(|v| v.parse().ok()) {
        cfg = cfg.with_pes(p);
    }
    let perf = NetworkPerf::model(&net, &cfg);
    println!("{} on {} ({} layers)", net.name, cfg.kind, perf.layers.len());
    println!(
        "{:<8} {:>6} {:>4} {:>4} {:>14} {:>14} {:>14}",
        "layer", "kind", "P", "Z", "compute(cy)", "fetch(cy)", "total(cy)"
    );
    for l in &perf.layers {
        println!(
            "{:<8} {:>6} {:>4} {:>4} {:>14} {:>14} {:>14}",
            l.name,
            if l.binary { "bin" } else { "int" },
            l.tiling.p,
            l.tiling.z,
            l.compute_cycles,
            l.fetch_cycles,
            l.total_cycles
        );
    }
    let conv = perf.conv_aggregate();
    let all = perf.total_aggregate();
    println!(
        "\nconv:  {:>8.1} MOp  {:>7.1} GOp/s  {:>9.1} uJ  {:>7.1} ms  {:>5.1} TOp/s/W",
        conv.mops, conv.gops, conv.energy_uj, conv.time_ms, conv.tops_per_w
    );
    println!(
        "all:   {:>8.1} MOp  {:>7.1} GOp/s  {:>9.1} uJ  {:>7.1} ms  {:>5.1} TOp/s/W",
        all.mops, all.gops, all.energy_uj, all.time_ms, all.tops_per_w
    );
    let e = perf.energy_breakdown();
    println!(
        "energy split: PE {:.1} uJ | MAC {:.1} uJ | memory {:.1} uJ | XNOR {:.1} uJ",
        e.pe_pj * 1e-6,
        e.mac_pj * 1e-6,
        e.memory_pj * 1e-6,
        e.xnor_pj * 1e-6
    );
}

fn cmd_schedule(args: &[String]) {
    let fanin: usize = match args.first().and_then(|a| a.parse().ok()) {
        Some(f) => f,
        None => usage(),
    };
    let t: i64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or((fanin / 2) as i64);
    let prog = adder_tree::threshold_node(fanin, t);
    println!(
        "threshold node: fanin={fanin} T'={t}\n  tree cycles {}  compare cycles {}  total {}\n  peak storage {} bits (of {} physical)\n  neuron evals {}  register accesses {:?}",
        prog.tree_cycles,
        prog.cmp_cycles,
        prog.total_cycles(),
        prog.peak_storage_bits,
        tulip::pe::NUM_REGS * tulip::pe::REG_BITS,
        prog.schedule.neuron_evals(),
        prog.schedule.reg_accesses(),
    );
}

fn cmd_golden(args: &[String]) {
    let stem = match args.first().map(String::as_str) {
        Some(s) => s,
        None => usage(),
    };
    let rt = tulip::runtime::Runtime::new("artifacts").expect("PJRT client");
    println!("platform: {}", rt.platform());
    match rt.load(stem) {
        Ok(model) => println!("loaded + compiled artifact '{}'", model.name),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

/// SIGINT/SIGTERM → request a graceful drain. Installed with the raw
/// libc `signal` syscall binding (no signal-handling crate in the vendored
/// set); the handler only sets an atomic flag, which is async-signal-safe.
#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        tulip::serve::request_drain();
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

fn cmd_model(args: &[String]) {
    match args.first().map(String::as_str) {
        Some("export") => {
            let name = match flag_value(args, "--model") {
                Some(n) => n,
                None => usage(),
            };
            let seed: u64 = flag_value(args, "--seed").and_then(|v| v.parse().ok()).unwrap_or(1000);
            let model = match name.as_str() {
                "tiny" | "tiny8" => Model::demo(&name).expect("demo name checked"),
                "binarynet" => {
                    Model::random(binarynet_cifar10(), seed).expect("zoo network is valid")
                }
                "alexnet" => Model::random(alexnet(), seed).expect("zoo network is valid"),
                other => {
                    eprintln!("unknown model '{other}' (tiny|tiny8|binarynet|alexnet)");
                    std::process::exit(2);
                }
            };
            let out = flag_value(args, "--out").unwrap_or_else(|| format!("{name}.model.json"));
            if let Err(e) = model.save(&out) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
            println!(
                "wrote {} ({} layers, {} weight bits) to {out}",
                model.name(),
                model.network().layers.len(),
                model.weight_bits()
            );
        }
        Some("inspect") => {
            let path = match args.get(1) {
                Some(p) => p,
                None => usage(),
            };
            let model = match Model::load(path) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(1);
                }
            };
            let net = model.network();
            let (h, w, c) = model.input_dims();
            println!("{path}: tulip.model/v1");
            println!("  network  {} ({})", model.name(), net.dataset);
            println!("  input    {h}x{w}x{c}  classes {}", model.num_classes());
            println!("  weights  {} bits across {} layers", model.weight_bits(), net.layers.len());
            match model.servable() {
                Ok(()) => println!("  servable yes"),
                Err(e) => println!("  servable no — {e}"),
            }
            for l in &net.layers {
                let (oh, ow) = l.output_spatial();
                println!(
                    "    {:<8} {:>4}x{:<4} z1 {:>4} -> z2 {:<4} k {} out {}x{}",
                    l.name, l.y1, l.x1, l.z1, l.z2, l.k, oh, ow
                );
            }
        }
        _ => usage(),
    }
}

fn cmd_serve(args: &[String]) {
    use tulip::coordinator::ForwardEngine;
    use tulip::serve::{serve, BackpressurePolicy, ServeConfig};

    let specs = {
        let s = flag_values(args, "--model");
        if s.is_empty() {
            vec!["tiny".to_string()]
        } else {
            s
        }
    };
    let mut models = Vec::new();
    for spec in &specs {
        match resolve_model(spec) {
            Ok(nm) => models.push(nm),
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
    let mut builder = ServeConfig::builder()
        .addr(flag_value(args, "--addr").unwrap_or_else(|| "127.0.0.1:7070".to_string()));
    if let Some(v) = flag_value(args, "--max-batch").and_then(|v| v.parse().ok()) {
        builder = builder.max_batch(v);
    }
    if let Some(v) = flag_value(args, "--max-wait-us").and_then(|v| v.parse().ok()) {
        builder = builder.max_wait_us(v);
    }
    if let Some(v) = flag_value(args, "--queue-cap").and_then(|v| v.parse().ok()) {
        builder = builder.queue_cap(v);
    }
    if let Some(p) = flag_value(args, "--policy") {
        builder = match BackpressurePolicy::from_name(&p) {
            Some(p) => builder.policy(p),
            None => {
                eprintln!("unknown policy '{p}' (block|reject)");
                std::process::exit(2);
            }
        };
    }
    if let Some(e) = flag_value(args, "--engine") {
        builder = match e.as_str() {
            "scalar" => builder.engine(ForwardEngine::Scalar),
            "bit_sliced" => builder.engine(ForwardEngine::BitSliced),
            other => {
                eprintln!("unknown engine '{other}' (scalar|bit_sliced)");
                std::process::exit(2);
            }
        };
    }
    if let Some(m) = flag_value(args, "--metrics-addr") {
        builder = builder.metrics_addr(m);
    }
    let cfg = builder.build();
    let perf_out = flag_value(args, "--perf-out");

    install_signal_handlers();
    let names: Vec<String> = models.iter().map(|(n, _)| n.clone()).collect();
    let handle = match serve(models, cfg.clone()) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    };
    println!(
        "tulip serve: [{}] on {} (max_batch {}, max_wait {} us, queue {} [{}])",
        names.join(", "),
        handle.local_addr(),
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.queue_cap,
        cfg.policy.name()
    );
    println!(
        "protocol tulip.serve/v1 — one JSON request per line; ctrl-c or {{\"op\": \"drain\"}} to \
         drain"
    );
    if let Some(maddr) = handle.metrics_addr() {
        println!("telemetry: http://{maddr}/metrics (also /healthz, /readyz, /trace)");
    }
    handle.wait_for_drain();
    println!("draining: flushing queued requests…");
    match handle.drain() {
        Ok(report) => {
            report.print_summary();
            if let Some(path) = perf_out {
                if let Err(e) = report.write_json(&path) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
                println!("serve report written to {path}");
            }
            if !report.accounted() {
                eprintln!("accounting discrepancy: admitted != completed + shed + failed");
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Pull the flight recorder from a running server (`{"op": "trace_dump"}`
/// over the wire protocol), write the `tulip.trace/v1` document, and
/// optionally convert it to Chrome `trace_event` JSON.
fn cmd_trace_dump(args: &[String]) {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use tulip::metrics::FlightDump;

    fn fail(msg: String) -> ! {
        eprintln!("error: {msg}");
        std::process::exit(1);
    }

    let addr = flag_value(args, "--addr").unwrap_or_else(|| "127.0.0.1:7070".to_string());
    let out = flag_value(args, "--out").unwrap_or_else(|| "trace.json".to_string());
    let mut stream = match TcpStream::connect(&addr) {
        Ok(s) => s,
        Err(e) => fail(format!("connecting {addr}: {e}")),
    };
    if let Err(e) = stream.write_all(b"{\"op\": \"trace_dump\"}\n").and_then(|()| stream.flush()) {
        fail(format!("sending trace_dump: {e}"));
    }
    let mut line = String::new();
    if let Err(e) = BufReader::new(stream).read_line(&mut line) {
        fail(format!("reading trace_dump reply: {e}"));
    }
    let dump = match FlightDump::parse(line.trim()) {
        Ok(d) => d,
        Err(e) => fail(format!("parsing trace_dump reply: {e:#}")),
    };
    if let Err(e) = std::fs::write(&out, format!("{}\n", dump.to_json_line())) {
        fail(format!("writing {out}: {e}"));
    }
    println!(
        "trace: {} events ({} dropped, ring capacity {}) written to {out}",
        dump.events.len(),
        dump.dropped,
        dump.capacity
    );
    if let Some(path) = flag_value(args, "--chrome") {
        if let Err(e) = std::fs::write(&path, dump.chrome_trace()) {
            fail(format!("writing {path}: {e}"));
        }
        println!("chrome trace written to {path} (open in chrome://tracing or Perfetto)");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("tables") => cmd_tables(&args[1..]),
        Some("table") => cmd_table(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("schedule") => cmd_schedule(&args[1..]),
        Some("golden") => cmd_golden(&args[1..]),
        Some("model") => cmd_model(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("trace-dump") => cmd_trace_dump(&args[1..]),
        _ => usage(),
    }
}

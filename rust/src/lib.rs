//! # TULIP — a configurable BNN accelerator built from programmable
//! threshold-logic standard cells.
//!
//! This crate is a full-system reproduction of
//! *"A Configurable BNN ASIC using a Network of Programmable Threshold Logic
//! Standard Cells"* (Wagle, Khatri, Vrudhula — ICCD 2020,
//! DOI 10.1109/ICCD50377.2020.00079).
//!
//! The paper's deliverable is silicon (TSMC 40nm-LP). This crate substitutes
//! the fab with a **bit-true, cycle-level microarchitecture simulator** plus
//! an **analytical area/power/energy model** whose per-unit constants are the
//! paper's own measurements (Tables I/II, Fig 7). See `DESIGN.md` for the
//! substitution argument and the experiment index.
//!
//! ## Layer map
//! * **L3 (this crate)** — the TULIP system: threshold-neuron cell model
//!   ([`neuron`]), the TULIP-PE ([`pe`]), the RPO adder-tree scheduler,
//!   all primitive schedules and the thread-safe program cache
//!   ([`scheduler`]), the YodaNN baseline ([`baseline`]), the top-level
//!   architecture ([`arch`]), the tiling / network-walk coordinator and
//!   the batched rayon-parallel inference engine ([`coordinator`]),
//!   the TCP serving front-end with micro-batching, backpressure and
//!   deadline shedding ([`serve`]),
//!   energy model ([`energy`]),
//!   BNN IR + model zoo ([`bnn`]), bit-true & analytic simulation engines
//!   ([`sim`]), PJRT golden-model runtime ([`runtime`]) and paper-table
//!   emitters ([`metrics`]).
//! * **L2/L1 (python, build-time only)** — JAX golden model + Pallas
//!   XNOR-popcount kernels, AOT-lowered to `artifacts/*.hlo.txt` and loaded
//!   by [`runtime`] — python never runs on the request path.
//!
//! ## Observability
//! Every run reports into the [`metrics`] layer: a thread-safe registry of
//! counters/gauges/histograms ([`metrics::MetricsRegistry`]), optional
//! tracing spans (`--features trace`, zero-cost no-ops by default) and a
//! machine-readable [`coordinator::PerfReport`] with per-layer
//! cycle/energy breakdowns, per-PE utilization and program-cache
//! statistics. `ARCHITECTURE.md` maps the paper's concepts onto these
//! modules.

#![warn(missing_docs)]

pub mod arch;
pub mod baseline;
pub mod bnn;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod metrics;
pub mod neuron;
pub mod pe;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod util;

pub use error::Error;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! The coordinator: tiling decisions (Table III), the layer-walk
//! performance model (Tables II/IV/V) and the paper-table report layer.
//!
//! This is the L3 "system contribution" layer of the reproduction: it owns
//! how a BNN is carved into OFM batches and IFM slabs, how those map onto
//! the PE/MAC arrays, and what the memory system does in the meantime. The
//! cycle counts it prices come from the *same* schedule objects the
//! bit-true engine executes, so the analytic model cannot drift from the
//! hardware model.

pub mod batch;
pub mod exec;
pub mod perf_report;
pub mod report;
pub mod tiling;

pub use batch::{
    argmax, BatchExecutor, BatchPerf, BatchRequest, BatchResult, ImageResult, WorkerSummary,
};
pub use crate::sim::cycle::ForwardEngine;
pub use exec::{LayerPerf, NetworkPerf};
pub use perf_report::{LayerReport, PeReport, PerfReport, ReportParts};
pub use tiling::{table3, tiling, Tiling};

//! Batched, data-parallel inference — the serving layer over the bit-true
//! engine.
//!
//! The paper's chip owes its throughput to a SIMD array of TULIP-PEs all
//! executing one broadcast control stream (§IV-E); a serving deployment of
//! the simulator owes its throughput to the same structure one level up:
//! **one shared [`ProgramCache`]** (schedules planned once per process) and
//! **many worker threads**, each owning a private PE array + sequence
//! generator and walking whole images independently. Workers share nothing
//! mutable — the cache hands out `Arc`s — so batching is deterministic by
//! construction: a [`BatchResult`] is bit-identical whether the batch ran
//! on one thread or sixteen, and its aggregate cycle/energy accounting is
//! exactly the sum of the per-image single-run numbers.
//!
//! ```no_run
//! use tulip::bnn::tensor::BitTensor;
//! use tulip::bnn::{tiny_bnn, Model};
//! use tulip::coordinator::{BatchExecutor, BatchRequest};
//!
//! let model = Model::random(tiny_bnn(16, 8, 4), 1000).unwrap();
//! let exec = BatchExecutor::for_model(&model).unwrap();
//! let req = BatchRequest::new((0..32).map(|i| BitTensor::random(16, 16, 8, i)).collect());
//! let result = exec.run(&req).unwrap();
//! println!("{:?} energy {:.1} nJ", result.classes(), result.energy().total_pj() * 1e-3);
//! ```

use crate::arch::unit::{PeArray, SlicedArray};
use crate::bnn::tensor::{BinWeights, BitTensor};
use crate::bnn::{Model, Network};
use crate::config::ArchConfig;
use crate::coordinator::exec::NetworkPerf;
use crate::energy::{calib, Activity, EnergyBreakdown, EnergyModel};
use crate::error::Error;
use crate::metrics::MetricsRegistry;
use crate::pe::PeStats;
use crate::scheduler::seqgen::SequenceGenerator;
use crate::scheduler::ProgramCache;
use crate::sim::cycle::{ForwardEngine, LayerObs};
use crate::Result;
use anyhow::ensure;
use rayon::prelude::*;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A batch of images to classify (HWC binary tensors matching the
/// network's input layer).
#[derive(Debug, Clone, Default)]
pub struct BatchRequest {
    /// The images, in the order results will be returned.
    pub images: Vec<BitTensor>,
}

impl BatchRequest {
    /// Wrap a list of images as a request.
    pub fn new(images: Vec<BitTensor>) -> Self {
        BatchRequest { images }
    }

    /// Number of images in the request.
    pub fn len(&self) -> usize {
        self.images.len()
    }

    /// Whether the request is empty. Empty requests are rejected by
    /// [`BatchExecutor::run`] — there is nothing to schedule, and silently
    /// returning an empty result would hide caller bugs (a batcher that
    /// flushed nothing).
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// Index of the maximum score; ties resolve to the lowest index, so the
/// classification is deterministic and thread-order independent.
pub fn argmax(scores: &[i64]) -> usize {
    assert!(!scores.is_empty(), "argmax of empty score vector");
    let mut best = 0;
    for (i, &s) in scores.iter().enumerate() {
        if s > scores[best] {
            best = i;
        }
    }
    best
}

/// Outcome for one image of a batch.
#[derive(Debug, Clone)]
pub struct ImageResult {
    /// Position in the originating [`BatchRequest`].
    pub index: usize,
    /// Raw final-layer popcount scores.
    pub scores: Vec<i64>,
    /// `argmax(scores)` — the predicted class.
    pub class: usize,
    /// Simulated chip cycles for this image alone.
    pub cycles: u64,
    /// PE activity for this image alone.
    pub stats: PeStats,
    /// Per-layer breakdown (partitions `cycles` and `stats` exactly; see
    /// [`LayerObs`]).
    pub layers: Vec<LayerObs>,
    /// Per-PE activity for this image, in array-flattened index order.
    pub per_pe: Vec<PeStats>,
    /// Host wall-clock nanoseconds this image's forward pass took on its
    /// worker thread (observability only — not part of the deterministic
    /// simulated result).
    pub host_ns: u64,
    /// Rayon worker index that ran this image (0 when run outside a pool).
    pub worker: usize,
}

impl ImageResult {
    /// This image's activity record for the energy model.
    pub fn activity(&self) -> Activity {
        self.stats.activity(self.cycles)
    }

    /// Energy priced at the calibrated model.
    pub fn energy(&self) -> EnergyBreakdown {
        EnergyModel::default().energy(&self.activity())
    }
}

/// Per-worker accounting of one batch: how many images each rayon worker
/// ran and how long it spent running them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Rayon worker index.
    pub worker: usize,
    /// Images this worker classified.
    pub images: usize,
    /// Summed host wall-clock nanoseconds across those images.
    pub busy_ns: u64,
}

/// Result of a batch execution: per-image results in request order plus
/// exact aggregates (every aggregate equals the sum of its per-image
/// parts — asserted by `tests/batch.rs`).
#[derive(Debug, Clone)]
pub struct BatchResult {
    /// Per-image results, in request order.
    pub images: Vec<ImageResult>,
    /// Simulated chip cycles summed over the batch.
    pub cycles: u64,
    /// PE activity summed over the batch.
    pub stats: PeStats,
    /// Host wall-clock time the batch took (all workers).
    pub wall: Duration,
}

impl BatchResult {
    /// Aggregate activity record (sum of per-image records).
    pub fn activity(&self) -> Activity {
        self.stats.activity(self.cycles)
    }

    /// Per-layer breakdown merged across the batch: entry `i` accumulates
    /// every image's record for layer `i`, so cycles and activity still
    /// partition the batch totals exactly.
    pub fn per_layer(&self) -> Vec<LayerObs> {
        let mut merged: Vec<LayerObs> = Vec::new();
        for img in &self.images {
            if merged.is_empty() {
                merged = img.layers.clone();
            } else {
                for (m, l) in merged.iter_mut().zip(&img.layers) {
                    m.merge(l);
                }
            }
        }
        merged
    }

    /// Per-PE activity merged element-wise across the batch (every worker
    /// simulates the same array geometry), in array-flattened index order.
    pub fn per_pe(&self) -> Vec<PeStats> {
        let mut merged: Vec<PeStats> = Vec::new();
        for img in &self.images {
            if merged.is_empty() {
                merged = img.per_pe.clone();
            } else {
                for (m, s) in merged.iter_mut().zip(&img.per_pe) {
                    m.merge(s);
                }
            }
        }
        merged
    }

    /// Per-worker image counts and busy time, sorted by worker index
    /// (rayon's work stealing makes the assignment nondeterministic — the
    /// simulated results are not).
    pub fn worker_summaries(&self) -> Vec<WorkerSummary> {
        let mut map: std::collections::BTreeMap<usize, WorkerSummary> =
            std::collections::BTreeMap::new();
        for img in &self.images {
            let w = map.entry(img.worker).or_default();
            w.worker = img.worker;
            w.images += 1;
            w.busy_ns += img.host_ns;
        }
        map.into_values().collect()
    }

    /// Aggregate energy priced at the calibrated model.
    pub fn energy(&self) -> EnergyBreakdown {
        EnergyModel::default().energy(&self.activity())
    }

    /// Predicted class per image, in request order.
    pub fn classes(&self) -> Vec<usize> {
        self.images.iter().map(|r| r.class).collect()
    }

    /// Host-side simulator throughput.
    pub fn images_per_sec(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s > 0.0 {
            self.images.len() as f64 / s
        } else {
            0.0
        }
    }

    /// Simulated on-chip latency per image, µs at the paper's 2.3 ns clock
    /// (averaged over the batch).
    pub fn simulated_us_per_image(&self) -> f64 {
        if self.images.is_empty() {
            return 0.0;
        }
        self.cycles as f64 / self.images.len() as f64 * calib::CLOCK_NS * 1e-3
    }
}

/// The batch executor: a frozen [`Model`], a shared program cache, and a
/// rayon-sharded bit-true backend. Construct once, serve many batches; the
/// executor is `Sync`, so one instance can serve concurrent callers. A
/// dedicated worker pool (when requested via
/// [`BatchExecutor::with_threads`]) is built once at configuration time,
/// not per batch.
pub struct BatchExecutor {
    model: Model,
    engine: ForwardEngine,
    cache: Arc<ProgramCache>,
    units: usize,
    pes_per_unit: usize,
    /// `None` ⇒ rayon's global pool.
    pool: Option<rayon::ThreadPool>,
}

impl std::fmt::Debug for BatchExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchExecutor")
            .field("network", &self.model.name())
            .field("layers", &self.model.network().layers.len())
            .field("engine", &self.engine)
            .field("units", &self.units)
            .field("pes_per_unit", &self.pes_per_unit)
            .field("dedicated_pool", &self.pool.is_some())
            .finish()
    }
}

/// A worker's private simulation state: the engine-specific array.
enum Scratch {
    Scalar(PeArray),
    Sliced(SlicedArray),
}

impl BatchExecutor {
    /// Build an executor for a servable [`Model`] (fully binary, FC
    /// classifier head — checked here, typed, not per batch). The model
    /// handle is cloned cheaply; its lane packing is primed eagerly, like
    /// the hardware's kernel-buffer load, so the first batch pays no
    /// packing cost.
    pub fn for_model(model: &Model) -> std::result::Result<Self, Error> {
        model.servable()?;
        model.sliced();
        Ok(BatchExecutor {
            model: model.clone(),
            engine: ForwardEngine::default(),
            cache: ProgramCache::global(),
            units: calib::NUM_MACS,
            pes_per_unit: calib::PES_PER_UNIT,
            pool: None,
        })
    }

    /// Deprecated tuple-shaped constructor — assemble a
    /// [`Model`](crate::bnn::Model) with [`Model::from_parts`] and call
    /// [`BatchExecutor::for_model`] instead.
    #[deprecated(
        since = "0.2.0",
        note = "build a bnn::Model and call BatchExecutor::for_model; removed next release"
    )]
    #[doc(hidden)]
    pub fn new(net: Network, weights: Vec<BinWeights>) -> Result<Self> {
        let model = Model::from_parts(net, weights)?;
        Ok(Self::for_model(&model)?)
    }

    /// Share a specific program cache (default: the process-global cache).
    pub fn with_cache(mut self, cache: Arc<ProgramCache>) -> Self {
        self.cache = cache;
        self
    }

    /// Select the execution path (default: [`ForwardEngine::BitSliced`]).
    /// Both engines produce bit-identical results; the scalar path is the
    /// reference oracle, the bit-sliced path runs 64 lanes per word.
    pub fn with_engine(mut self, engine: ForwardEngine) -> Self {
        self.engine = engine;
        self
    }

    /// The execution path this executor drives.
    pub fn engine(&self) -> ForwardEngine {
        self.engine
    }

    /// Per-worker PE-array geometry (default: the paper's 32 × 8 = 256).
    pub fn with_array(mut self, units: usize, pes_per_unit: usize) -> Self {
        assert!(units >= 1 && pes_per_unit >= 1);
        self.units = units;
        self.pes_per_unit = pes_per_unit;
        self
    }

    /// Worker-thread count; `0` (the default) uses rayon's global pool.
    /// A non-zero count builds a dedicated pool **once**, here, reused by
    /// every subsequent [`BatchExecutor::run`].
    ///
    /// # Panics
    /// Panics if the dedicated pool cannot be created.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.pool = if threads == 0 {
            None
        } else {
            let pool = rayon::ThreadPoolBuilder::new()
                .num_threads(threads)
                .build()
                .expect("building the batch worker pool");
            Some(pool)
        };
        self
    }

    /// The frozen model this executor serves.
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The frozen network this executor serves (shorthand for
    /// `model().network()`).
    pub fn network(&self) -> &Network {
        self.model.network()
    }

    /// A handle on this executor's shared program cache (for snapshotting
    /// hit/miss/planning stats into reports).
    pub fn cache_handle(&self) -> Arc<ProgramCache> {
        Arc::clone(&self.cache)
    }

    fn classify(
        &self,
        scratch: &mut Scratch,
        sg: &mut SequenceGenerator,
        index: usize,
        image: &BitTensor,
    ) -> ImageResult {
        let _span = crate::metrics::span("batch.image");
        let t0 = Instant::now();
        let f = match scratch {
            Scratch::Scalar(array) => self.model.forward_scalar(array, sg, image),
            Scratch::Sliced(arr) => self.model.forward_sliced(arr, sg, image),
        };
        let host_ns = t0.elapsed().as_nanos() as u64;
        let class = argmax(&f.scores);
        ImageResult {
            index,
            scores: f.scores,
            class,
            cycles: f.cycles,
            stats: f.stats,
            layers: f.layers,
            per_pe: f.per_pe,
            host_ns,
            worker: rayon::current_thread_index().unwrap_or(0),
        }
    }

    fn scratch(&self) -> (Scratch, SequenceGenerator) {
        let scratch = match self.engine {
            ForwardEngine::Scalar => Scratch::Scalar(PeArray::new(self.units, self.pes_per_unit)),
            ForwardEngine::BitSliced => {
                Scratch::Sliced(SlicedArray::new(self.units, self.pes_per_unit))
            }
        };
        (scratch, SequenceGenerator::with_cache(Arc::clone(&self.cache)))
    }

    /// Classify one image on a private scratch array — the per-image
    /// single-run baseline batch aggregates are checked against.
    pub fn run_one(&self, index: usize, image: &BitTensor) -> Result<ImageResult> {
        self.check_image(index, image)?;
        let (mut scratch, mut sg) = self.scratch();
        Ok(self.classify(&mut scratch, &mut sg, index, image))
    }

    /// Run a batch: images are sharded across worker threads (each with
    /// its own PE array and generator, all sharing this executor's program
    /// cache) and results are returned in request order. Aggregate
    /// counters are published to [`MetricsRegistry::global`] after every
    /// batch.
    ///
    /// ```
    /// use tulip::bnn::tensor::BitTensor;
    /// use tulip::bnn::{tiny_bnn, Model};
    /// use tulip::coordinator::{BatchExecutor, BatchRequest};
    ///
    /// let model = Model::random(tiny_bnn(8, 4, 3), 1)?;
    /// let exec = BatchExecutor::for_model(&model)?.with_array(1, 4);
    /// let req = BatchRequest::new(vec![BitTensor::random(8, 8, 4, 9)]);
    /// let result = exec.run(&req)?;
    /// assert_eq!(result.images.len(), 1);
    /// // Per-layer records partition the totals exactly.
    /// let layer_cycles: u64 = result.per_layer().iter().map(|l| l.cycles).sum();
    /// assert_eq!(layer_cycles, result.cycles);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn run(&self, req: &BatchRequest) -> Result<BatchResult> {
        let _span = crate::metrics::span("batch.run");
        ensure!(!req.is_empty(), "empty batch: a BatchRequest must contain at least one image");
        // Every image must match the network's input layer — which also
        // guarantees all images in the batch agree with *each other*, so
        // nothing deeper in the packing path ever sees mixed shapes.
        for (i, img) in req.images.iter().enumerate() {
            self.check_image(i, img)?;
        }
        let t0 = Instant::now();
        let images = self.run_sharded(req);
        let mut stats = PeStats::default();
        let mut cycles = 0u64;
        for r in &images {
            stats.merge(&r.stats);
            cycles += r.cycles;
        }
        let result = BatchResult { images, cycles, stats, wall: t0.elapsed() };
        self.publish_to(MetricsRegistry::global(), &result);
        Ok(result)
    }

    /// Report one batch's aggregates into a metrics registry: batch/image
    /// counters, wall-time histograms, PE utilization, the energy
    /// breakdown and the program cache's counters. [`BatchExecutor::run`]
    /// calls this with the global registry; call it directly to account
    /// into a scoped registry instead. Cost is a few dozen atomic ops per
    /// *batch*, so it is invisible next to the simulation itself.
    pub fn publish_to(&self, registry: &MetricsRegistry, result: &BatchResult) {
        registry.counter("batch.runs").inc();
        registry.counter("batch.images").add(result.images.len() as u64);
        registry.counter("batch.sim_cycles").add(result.cycles);
        registry.counter("pe.neuron_evals").add(result.stats.neuron_evals);
        registry.counter("pe.gated_neuron_cycles").add(result.stats.gated_neuron_cycles);
        registry
            .counter("pe.reg_accesses")
            .add(result.stats.reg_reads + result.stats.reg_writes);
        registry.histogram("batch.wall_us").observe(result.wall.as_micros() as u64);
        let image_host = registry.histogram("image.host_us");
        // Per-engine histogram alongside the aggregate, so scalar and
        // bit-sliced latencies stay separable in one registry.
        let image_host_engine =
            registry.histogram(&format!("image.host_us.{}", self.engine.name()));
        for img in &result.images {
            image_host.observe(img.host_ns / 1_000);
            image_host_engine.observe(img.host_ns / 1_000);
        }
        // 0 = scalar oracle, 1 = bit-sliced: which path produced the
        // numbers currently in this registry.
        registry.gauge("batch.engine").set(match self.engine {
            ForwardEngine::Scalar => 0.0,
            ForwardEngine::BitSliced => 1.0,
        });
        registry.gauge("batch.images_per_sec").set(result.images_per_sec());
        registry.gauge("pe.utilization").set(result.stats.utilization());
        let energy = result.energy();
        if !result.images.is_empty() {
            registry
                .gauge("batch.energy_per_classification_pj")
                .set(energy.total_pj() / result.images.len() as f64);
        }
        energy.publish_to(registry, "batch.energy");
        self.cache.publish_to(registry);
    }

    fn check_image(&self, index: usize, img: &BitTensor) -> Result<()> {
        let (h, w, c) = self.model.input_dims();
        if img.h != h || img.w != w || img.c != c {
            return Err(Error::ShapeMismatch(format!(
                "image {index}: got {}x{}x{}, network expects {h}x{w}x{c}",
                img.h, img.w, img.c
            ))
            .into());
        }
        Ok(())
    }

    fn run_sharded(&self, req: &BatchRequest) -> Vec<ImageResult> {
        let _span = crate::metrics::span("batch.shard");
        let work = || {
            req.images
                .par_iter()
                .enumerate()
                .map_init(
                    || self.scratch(),
                    |(scratch, sg), (index, image)| self.classify(scratch, sg, index, image),
                )
                .collect()
        };
        match &self.pool {
            Some(pool) => pool.install(work),
            None => work(),
        }
    }
}

/// Analytic (non-bit-true) batch performance: the coordinator's
/// single-image layer-walk model scaled to a batch. Because every image of
/// a batch walks the same schedule objects, the batched accounting is
/// *exactly* `batch ×` the single-image analytic model — no drift between
/// the serving path and the paper-table path.
#[derive(Debug, Clone)]
pub struct BatchPerf {
    /// The single-image analytic model being scaled.
    pub per_image: NetworkPerf,
    /// Batch size the aggregates are scaled by.
    pub batch: usize,
}

impl BatchPerf {
    /// Model a batch of `batch` images on architecture `cfg`.
    pub fn model(net: &Network, cfg: &ArchConfig, batch: usize) -> Self {
        BatchPerf { per_image: NetworkPerf::model(net, cfg), batch }
    }

    /// Total chip cycles for the batch — exactly `batch ×` one image.
    pub fn total_cycles(&self) -> u64 {
        self.per_image.total_aggregate().cycles * self.batch as u64
    }

    /// Aggregate activity — exactly `batch ×` the single-image record.
    pub fn activity(&self) -> Activity {
        let mut a = Activity::default();
        for l in &self.per_image.layers {
            a.merge(&l.activity);
        }
        a.scaled(self.batch as u64)
    }

    /// Aggregate energy at the calibrated model.
    pub fn energy(&self) -> EnergyBreakdown {
        EnergyModel::default().energy(&self.activity())
    }

    /// Simulated steady-state throughput at the paper's clock (one chip,
    /// images back to back).
    pub fn images_per_sec(&self) -> f64 {
        let per = EnergyModel::default().seconds(self.per_image.total_aggregate().cycles);
        if per > 0.0 {
            1.0 / per
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::reference;
    use crate::bnn::tiny_bnn;

    fn tiny_executor() -> BatchExecutor {
        let model = Model::random(tiny_bnn(8, 4, 3), 7).unwrap();
        BatchExecutor::for_model(&model).unwrap().with_array(1, 4)
    }

    #[test]
    fn argmax_first_tie_wins() {
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[3]), 0);
        assert_eq!(argmax(&[-4, -2, -9]), 1);
    }

    #[test]
    fn batch_matches_functional_reference() {
        let exec = tiny_executor();
        let req = BatchRequest::new((0..5).map(|i| BitTensor::random(8, 8, 4, 40 + i)).collect());
        let got = exec.run(&req).unwrap();
        assert_eq!(got.images.len(), 5);
        let net = tiny_bnn(8, 4, 3);
        let weights: Vec<BinWeights> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 7 + i as u64))
            .collect();
        for (i, r) in got.images.iter().enumerate() {
            assert_eq!(r.index, i, "request order preserved");
            let expect = reference::forward_scores(&net, &req.images[i], &weights);
            assert_eq!(r.scores, expect, "image {i}");
            assert_eq!(r.class, argmax(&expect));
        }
        assert!(got.cycles > 0 && got.stats.neuron_evals > 0);
        assert!(got.energy().total_pj() > 0.0);
    }

    #[test]
    fn executor_rejects_bad_inputs() {
        use crate::bnn::layer::LayerKind;
        use crate::bnn::{Layer, Network};
        // Integer layer → typed Unservable at construction.
        let net = Network {
            name: "int".into(),
            dataset: "t".into(),
            layers: vec![
                Layer::conv("c", LayerKind::ConvInt, (8, 8, 3), 3, 1, 1, 4, None),
                Layer::fc("f", LayerKind::FcBin, 8 * 8 * 4, 2),
            ],
        };
        let w: Vec<BinWeights> =
            net.layers.iter().map(|l| BinWeights::random(l.z2, l.fanin(), 1)).collect();
        let model = Model::from_parts(net, w).unwrap();
        assert!(matches!(BatchExecutor::for_model(&model), Err(Error::Unservable(_))));
        // Weight shape mismatch → typed InvalidNetwork at model assembly.
        let net = tiny_bnn(8, 4, 3);
        let mut w: Vec<BinWeights> =
            net.layers.iter().map(|l| BinWeights::random(l.z2, l.fanin(), 1)).collect();
        w[1] = BinWeights::random(3, 9, 1);
        assert!(matches!(Model::from_parts(net, w), Err(Error::InvalidNetwork(_))));
        // Wrong image geometry → rejected per request.
        let exec = tiny_executor();
        let req = BatchRequest::new(vec![BitTensor::random(4, 4, 4, 1)]);
        assert!(exec.run(&req).is_err());
    }

    /// Engine selection: scalar and bit-sliced batches are bit-identical,
    /// and each engine tags the registry it publishes into.
    #[test]
    fn engines_agree_and_publish() {
        let scalar = tiny_executor().with_engine(ForwardEngine::Scalar);
        let sliced = tiny_executor();
        assert_eq!(sliced.engine(), ForwardEngine::BitSliced, "bit-sliced is the default");
        let req = BatchRequest::new((0..3).map(|i| BitTensor::random(8, 8, 4, 70 + i)).collect());
        let a = scalar.run(&req).unwrap();
        let b = sliced.run(&req).unwrap();
        assert_eq!(a.classes(), b.classes());
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.stats, b.stats);
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x.scores, y.scores);
            assert_eq!(x.layers, y.layers);
            assert_eq!(x.per_pe, y.per_pe);
        }
        let reg = MetricsRegistry::new();
        sliced.publish_to(&reg, &b);
        assert_eq!(reg.gauge("batch.engine").get(), 1.0);
        assert_eq!(reg.histogram("image.host_us.bit_sliced").snapshot().count, 3);
        let per_image = reg.gauge("batch.energy_per_classification_pj").get();
        assert!((per_image - b.energy().total_pj() / 3.0).abs() < 1e-9, "per-image energy gauge");
        let reg = MetricsRegistry::new();
        scalar.publish_to(&reg, &a);
        assert_eq!(reg.gauge("batch.engine").get(), 0.0);
        assert_eq!(reg.histogram("image.host_us.scalar").snapshot().count, 3);
    }

    #[test]
    fn empty_batch_is_a_clean_error() {
        let exec = tiny_executor();
        let err = exec.run(&BatchRequest::default()).unwrap_err();
        assert!(err.to_string().contains("empty batch"), "{err}");
    }

    #[test]
    fn mixed_shape_batch_is_a_clean_error() {
        let exec = tiny_executor();
        // First image is valid, second disagrees — the error names the
        // offending index instead of panicking deep in packing.
        let req = BatchRequest::new(vec![
            BitTensor::random(8, 8, 4, 1),
            BitTensor::random(8, 4, 4, 2),
        ]);
        let err = exec.run(&req).unwrap_err();
        assert!(err.to_string().contains("image 1"), "{err}");
    }

    #[test]
    fn batch_perf_scales_exactly() {
        let net = crate::bnn::binarynet_cifar10();
        let cfg = ArchConfig::tulip();
        let single = NetworkPerf::model(&net, &cfg);
        let bp = BatchPerf::model(&net, &cfg, 17);
        assert_eq!(bp.total_cycles(), single.total_aggregate().cycles * 17);
        let mut one = Activity::default();
        for l in &single.layers {
            one.merge(&l.activity);
        }
        assert_eq!(bp.activity(), one.scaled(17));
        assert!(bp.images_per_sec() > 0.0);
    }
}

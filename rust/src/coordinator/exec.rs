//! The analytic performance model: walks a network layer by layer and
//! derives cycle counts and activity from the *same* schedule objects the
//! bit-true engine executes (via the sequence generator), plus the MAC
//! cycle model and the memory traffic model. This is the engine behind
//! Tables II, IV and V.

use crate::arch::memory::{conv_traffic, fc_traffic, LayerTraffic};
use crate::baseline::MacUnit;
use crate::bnn::{Layer, Network};
use crate::config::{ArchConfig, ArchKind};
use crate::coordinator::tiling::{tiling, Tiling};
use crate::energy::{calib, Activity, EnergyModel};
use crate::scheduler::seqgen::{OpDesc, SequenceGenerator};

/// Cost of executing one BNN node (one output activation) on a TULIP-PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeCost {
    /// PE cycles for the node.
    pub cycles: u64,
    /// Non-gated neuron evaluations.
    pub neuron_evals: u64,
    /// Register bit reads + writes.
    pub reg_accesses: u64,
    /// Number of chunked passes (1 when the fan-in fits one adder tree).
    pub passes: u64,
}

/// Cycle/energy cost of an `n`-input threshold node computed as up to
/// `slab_fanin`-input adder-tree passes accumulated per Fig. 4(c), plus the
/// final comparison (§IV-B/C).
pub fn pe_node_cost(sg: &mut SequenceGenerator, fanin: usize, slab_fanin: usize) -> NodeCost {
    assert!(fanin >= 1 && slab_fanin >= 1);
    let total_width = 64 - (fanin as u64).leading_zeros() as u64 + 1; // ⌈log2(fanin+1)⌉
    if fanin <= slab_fanin {
        let prog = sg.program(&OpDesc::ThresholdNode { n: fanin, t_popcount: (fanin / 2) as i64 });
        let (r, w) = prog.schedule.reg_accesses();
        return NodeCost {
            cycles: prog.schedule.cycles() as u64,
            neuron_evals: prog.schedule.neuron_evals(),
            reg_accesses: r + w,
            passes: 1,
        };
    }
    // Chunked: P = ⌈fanin/slab⌉ sum-tree passes + (P−1) accumulations of
    // the running partial sum (alternating registers, Fig. 4c) + one final
    // threshold comparison.
    let mut cycles = 0u64;
    let mut evals = 0u64;
    let mut regs = 0u64;
    let mut passes = 0u64;
    let mut remaining = fanin;
    while remaining > 0 {
        let n = remaining.min(slab_fanin);
        remaining -= n;
        passes += 1;
        let prog = sg.program(&OpDesc::SumTree { n });
        cycles += prog.schedule.cycles() as u64;
        evals += prog.schedule.neuron_evals();
        let (r, w) = prog.schedule.reg_accesses();
        regs += r + w;
    }
    // Accumulations: each is a bit-serial add at (growing) partial width;
    // bounded by the total width. 2 active neurons + ~3 register bit
    // accesses per cycle (two operand reads + result write).
    let acc_cycles = (passes - 1) * (total_width + 1);
    cycles += acc_cycles;
    evals += acc_cycles * 2;
    regs += acc_cycles * 3;
    // Final threshold comparison at full width (1 active neuron/cycle).
    cycles += total_width;
    evals += total_width;
    regs += total_width * 2;
    NodeCost { cycles, neuron_evals: evals, reg_accesses: regs, passes }
}

/// Cycle cost of an **integer** node on a TULIP-PE — the design-decision
/// ablation behind §V-C's "Although the TULIP-PEs are capable of handling
/// the integer layers as well, it would result in reduced throughput. This
/// is because the TULIP-PEs require several cycles for integer additions,
/// which becomes progressively worse as the size of the operands increase.
/// Hence, MACs are used for integer layers."
///
/// With `bits`-wide activations the adder tree's operands start at `bits`
/// width instead of 1, so every internal node is a `(bits + level)`-cycle
/// bit-serial addition: the tree costs ≈ `fanin · bits` cycles instead of
/// ≈ `1.3 · fanin / 3`.
pub fn pe_int_node_cycles(fanin: usize, bits: u32) -> u64 {
    assert!(fanin >= 1);
    // Binary combine over `fanin` operands of initial width `bits`:
    // level ℓ (1-based) has fanin/2^ℓ adds of width (bits + ℓ - 1).
    let mut cycles = 0u64;
    let mut count = fanin as u64;
    let mut width = bits as u64;
    while count > 1 {
        let pairs = count / 2;
        cycles += pairs * width;
        count -= pairs; // pairs results + possible odd leftover
        width += 1;
    }
    cycles + width // final threshold comparison
}

/// Per-layer performance on one architecture.
#[derive(Debug, Clone)]
pub struct LayerPerf {
    /// Layer name from the network description.
    pub name: String,
    /// Whether the layer runs on the binary (PE) datapath.
    pub binary: bool,
    /// Whether the layer is convolutional.
    pub is_conv: bool,
    /// Binary ops in the layer (2 × fanin per output, the paper's MOP
    /// convention).
    pub ops: u64,
    /// Tiling decision the cycle counts assume.
    pub tiling: Tiling,
    /// Cycles the processing array is busy.
    pub compute_cycles: u64,
    /// Cycles the memory system needs to feed the layer.
    pub fetch_cycles: u64,
    /// Wall-clock cycles: compute and fetch overlap through the
    /// double-buffered L2 (§IV-E), so the layer takes the max of the two.
    pub total_cycles: u64,
    /// Activity record priced by the energy model.
    pub activity: Activity,
}

/// Model one layer.
pub fn layer_perf(layer: &Layer, cfg: &ArchConfig, sg: &mut SequenceGenerator) -> LayerPerf {
    let t = tiling(layer, cfg);
    let traffic: LayerTraffic = if layer.is_conv() {
        conv_traffic(layer, &t, cfg)
    } else {
        fc_traffic(layer, &t, cfg)
    };
    let mut act = traffic.activity;

    let (x2, y2) = layer.output_spatial();
    let pixels = (x2 * y2) as u64;
    let zb = t.z as u64;

    let compute_cycles: u64;
    if t.on_pes {
        // ---- TULIP-PE path (binary conv / binary FC) ----
        let slab_fanin = if layer.is_conv() {
            layer.k * layer.k * layer.z1.min(t.slab_ifms)
        } else {
            // FC chunks are sized by the PE's direct tree capacity.
            layer.z1.min(cfg.max_tree_fanin.min(768))
        };
        let node = pe_node_cost(sg, layer.fanin(), slab_fanin);
        let nodes_per_batch = pixels; // each PE walks all pixels of its OFM
        let mut cycles = zb * nodes_per_batch * node.cycles;
        // Fused max-pooling on the same PEs (Fig. 5b).
        let mut pool_evals = 0u64;
        if let Some((pk, ps)) = layer.pool {
            let px = ((x2 - pk) / ps + 1) as u64 * ((y2 - pk) / ps + 1) as u64;
            let pool = sg.program(&OpDesc::Maxpool { n: pk * pk });
            cycles += zb * px * pool.schedule.cycles() as u64;
            pool_evals = pool.schedule.neuron_evals() * px * layer.z2 as u64;
        }
        compute_cycles = cycles;
        // Activity: every OFM channel executes the node program once per
        // pixel (z2 total across batches).
        let execs = pixels * layer.z2 as u64;
        act.pe_neuron_evals = node.neuron_evals * execs + pool_evals;
        act.pe_reg_accesses = node.reg_accesses * execs;
        // Clocked-but-gated neuron-cycles across the whole array.
        let array_neuron_cycles = compute_cycles * (cfg.num_pes as u64) * 4;
        act.pe_gated_neuron_cycles = array_neuron_cycles.saturating_sub(act.pe_neuron_evals);
    } else {
        // ---- MAC path (integer layers; all YodaNN layers) ----
        let mac =
            if cfg.kind == ArchKind::Yodann { MacUnit::yodann() } else { MacUnit::simplified() };
        let cycles_per_window: u64 = if layer.is_conv() {
            // P slab passes per window; the last slab may be partial.
            let mut c = 0u64;
            let mut remaining = layer.z1;
            while remaining > 0 {
                let ifms = remaining.min(t.slab_ifms);
                remaining -= ifms;
                c += mac.window_cycles(layer.k.min(7), ifms);
            }
            c
        } else {
            // FC: element-wise products at the same 2·k²-per-cycle datapath
            // rate (§V-A: "we estimate the throughput and power by
            // performing an element-wise matrix multiplication").
            (layer.z1 as u64).div_ceil(18) + 1
        };
        compute_cycles = zb * pixels * cycles_per_window;
        let active_units = layer.z2.min(t.ofm_batch) as u64;
        let unit_cycles = compute_cycles * active_units;
        match (cfg.kind, layer.is_binary()) {
            (ArchKind::Yodann, true) => act.mac_bin_cycles = unit_cycles,
            (ArchKind::Yodann, false) => act.mac_int_cycles = unit_cycles,
            (ArchKind::Tulip, _) => act.simple_mac_cycles = unit_cycles,
        }
    }

    let fetch_cycles = traffic.fetch_cycles;
    let total_cycles = compute_cycles.max(fetch_cycles);
    // Units idle while the layer is fetch-bound.
    let idle = total_cycles - compute_cycles;
    if t.on_pes {
        act.pe_gated_neuron_cycles += idle * (cfg.num_pes as u64) * 4;
    } else {
        act.mac_idle_cycles += idle * cfg.num_macs as u64;
    }
    act.total_cycles = total_cycles;

    LayerPerf {
        name: layer.name.clone(),
        binary: layer.is_binary(),
        is_conv: layer.is_conv(),
        ops: layer.ops(),
        tiling: t,
        compute_cycles,
        fetch_cycles,
        total_cycles,
        activity: act,
    }
}

/// Whole-network performance report.
#[derive(Debug, Clone)]
pub struct NetworkPerf {
    /// Architecture the model was run for.
    pub arch: ArchKind,
    /// Network name.
    pub network: String,
    /// Dataset label (reporting only).
    pub dataset: String,
    /// Per-layer results, in network order.
    pub layers: Vec<LayerPerf>,
}

/// Aggregate metrics over a subset of layers (Table IV = conv only,
/// Table V = all layers).
#[derive(Debug, Clone, Copy)]
pub struct Aggregate {
    /// Millions of binary ops in scope.
    pub mops: f64,
    /// Total wall-clock cycles.
    pub cycles: u64,
    /// Wall-clock time at the calibrated clock period.
    pub time_ms: f64,
    /// Total energy in microjoules.
    pub energy_uj: f64,
    /// Throughput, giga-ops per second.
    pub gops: f64,
    /// Energy efficiency, tera-ops per watt.
    pub tops_per_w: f64,
    /// Average power draw in milliwatts.
    pub avg_power_mw: f64,
}

impl NetworkPerf {
    /// Run the model for a network on an architecture. Programs come from
    /// the process-global schedule cache (§IV-E: one sequence generator,
    /// broadcast) — each distinct layer shape is planned once per process,
    /// no matter how many models, batches or threads ask for it.
    pub fn model(net: &Network, cfg: &ArchConfig) -> Self {
        let mut sg = SequenceGenerator::with_cache(crate::scheduler::ProgramCache::global());
        Self::model_with(net, cfg, &mut sg)
    }

    /// Run the model with a caller-provided sequence generator (private
    /// cache accounting, or a cache built for non-default arch params).
    pub fn model_with(net: &Network, cfg: &ArchConfig, sg: &mut SequenceGenerator) -> Self {
        let layers = net.layers.iter().map(|l| layer_perf(l, cfg, &mut *sg)).collect();
        NetworkPerf {
            arch: cfg.kind,
            network: net.name.clone(),
            dataset: net.dataset.clone(),
            layers,
        }
    }

    fn aggregate_filtered(&self, keep: impl Fn(&LayerPerf) -> bool) -> Aggregate {
        let model = EnergyModel::default();
        let mut act = Activity::default();
        let mut ops = 0u64;
        let mut cycles = 0u64;
        for l in self.layers.iter().filter(|l| keep(l)) {
            act.merge(&l.activity);
            ops += l.ops;
            cycles += l.total_cycles;
        }
        let time_s = model.seconds(cycles);
        let energy = model.energy(&act);
        let e_j = energy.total_pj() * 1e-12;
        Aggregate {
            mops: ops as f64 / 1e6,
            cycles,
            time_ms: time_s * 1e3,
            energy_uj: e_j * 1e6,
            gops: if time_s > 0.0 { ops as f64 / time_s / 1e9 } else { 0.0 },
            tops_per_w: if e_j > 0.0 { ops as f64 / e_j / 1e12 } else { 0.0 },
            avg_power_mw: if time_s > 0.0 { e_j / time_s * 1e3 } else { 0.0 },
        }
    }

    /// Table IV scope: convolution layers only.
    pub fn conv_aggregate(&self) -> Aggregate {
        self.aggregate_filtered(|l| l.is_conv)
    }

    /// Table V scope: the entire network.
    pub fn total_aggregate(&self) -> Aggregate {
        self.aggregate_filtered(|_| true)
    }

    /// Energy breakdown over all layers (for EXPERIMENTS.md analysis).
    pub fn energy_breakdown(&self) -> crate::energy::EnergyBreakdown {
        let model = EnergyModel::default();
        let mut act = Activity::default();
        for l in &self.layers {
            act.merge(&l.activity);
        }
        model.energy(&act)
    }
}

/// Clock-anchored helper: cycles → milliseconds at the paper's 2.3 ns.
pub fn cycles_to_ms(cycles: u64) -> f64 {
    cycles as f64 * calib::CLOCK_NS * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{alexnet, binarynet_cifar10};

    /// The §V-C design decision quantified: an integer (12-bit) 288-input
    /// node on a PE costs ~10x the binary node and ~200x the MAC's 17
    /// cycles — which is exactly why TULIP routes integer layers to MACs.
    #[test]
    fn integer_on_pe_is_prohibitive() {
        let mut sg = SequenceGenerator::new();
        let binary = pe_node_cost(&mut sg, 288, 288).cycles;
        let integer = pe_int_node_cycles(288, 12);
        assert!(integer > 8 * binary, "int {integer} vs bin {binary}");
        assert!(integer > 100 * 17, "int {integer} vs MAC 17 cycles");
        // And it gets "progressively worse as the size of the operands
        // increase" — superlinear in bits.
        assert!(pe_int_node_cycles(288, 12) > pe_int_node_cycles(288, 4) * 2);
    }

    /// Table II anchor: the 288-input node on a TULIP-PE lands in the
    /// regime of the paper's 441 cycles (see EXPERIMENTS.md §Table II).
    #[test]
    fn node_cost_288() {
        let mut sg = SequenceGenerator::new();
        let c = pe_node_cost(&mut sg, 288, 288);
        assert!(c.cycles >= 300 && c.cycles <= 550, "{}", c.cycles);
        assert_eq!(c.passes, 1);
    }

    /// Chunked node: fan-in larger than one slab accumulates per Fig. 4(c).
    #[test]
    fn node_cost_chunked() {
        let mut sg = SequenceGenerator::new();
        let whole = pe_node_cost(&mut sg, 288, 288);
        let chunked = pe_node_cost(&mut sg, 1152, 288);
        assert_eq!(chunked.passes, 4);
        // Chunked cost ≈ 4 tree passes + 3 accumulates + compare: strictly
        // more than 4× the single tree, bounded by 4× the full node.
        assert!(chunked.cycles > 3 * whole.cycles);
        assert!(chunked.cycles < 5 * whole.cycles);
    }

    /// The model is deterministic and the sequence-generator cache works
    /// across layers.
    #[test]
    fn model_deterministic() {
        let net = binarynet_cifar10();
        let a = NetworkPerf::model(&net, &ArchConfig::tulip());
        let b = NetworkPerf::model(&net, &ArchConfig::tulip());
        assert_eq!(a.total_aggregate().cycles, b.total_aggregate().cycles);
    }

    /// Directional anchors from Table IV/V: TULIP beats YodaNN on energy
    /// for conv layers by ≥ 2×, with throughput within ±40%.
    #[test]
    fn tulip_vs_yodann_shape() {
        for net in [binarynet_cifar10(), alexnet()] {
            let t = NetworkPerf::model(&net, &ArchConfig::tulip());
            let y = NetworkPerf::model(&net, &ArchConfig::yodann());
            let (tc, yc) = (t.conv_aggregate(), y.conv_aggregate());
            let e_ratio = yc.energy_uj / tc.energy_uj;
            assert!(e_ratio > 2.0, "{}: conv energy ratio {e_ratio}", net.name);
            let perf_ratio = tc.gops / yc.gops;
            assert!(
                (0.6..=2.5).contains(&perf_ratio),
                "{}: conv perf ratio {perf_ratio}",
                net.name
            );
            // All-layer efficiency still favours TULIP (Table V: 2.4–2.7×).
            let (tt, yt) = (t.total_aggregate(), y.total_aggregate());
            assert!(yt.energy_uj / tt.energy_uj > 1.8, "{}: total", net.name);
        }
    }

    /// FC layers are stream-bound on both architectures (§V-C).
    #[test]
    fn fc_layers_fetch_bound() {
        let net = alexnet();
        let perf = NetworkPerf::model(&net, &ArchConfig::tulip());
        for l in perf.layers.iter().filter(|l| !l.is_conv) {
            assert!(l.fetch_cycles > l.compute_cycles, "{}", l.name);
        }
    }

    /// Integer layers cost the same cycles on both designs (both use MACs).
    #[test]
    fn integer_layers_same_cycles() {
        let net = alexnet();
        let t = NetworkPerf::model(&net, &ArchConfig::tulip());
        let y = NetworkPerf::model(&net, &ArchConfig::yodann());
        for (lt, ly) in t.layers.iter().zip(&y.layers).filter(|(l, _)| !l.binary) {
            assert_eq!(lt.compute_cycles, ly.compute_cycles, "{}", lt.name);
        }
    }
}

//! Tiling: OFM batching, partial-product passes (P) and input refetch
//! counts (Z) — the model behind Table III.
//!
//! §V-C: both designs keep 32 IFMs on-chip per slab. For kernels with
//! `k ≤ 5` the MAC datapath fetches **two** IFMs per cycle, so a slab holds
//! 64 IFMs worth of partial products (`P = ⌈z1/64⌉`); the TULIP-PEs always
//! consume 32-IFM slabs (`P = ⌈z1/32⌉`). OFMs are produced in batches of 32
//! (MAC path) or 256 (TULIP-PE path), and the IFMs are refetched for each
//! batch: `Z = ⌈z2/32⌉` resp. `⌈z2/256⌉`. The total input-refetch pressure
//! is `P × Z`, where TULIP's 8× wider binary-layer batching is what buys
//! the 3–4× reduction the paper reports.

use crate::bnn::Layer;
use crate::config::{ArchConfig, ArchKind};

/// Tiling decision for one layer on one architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    /// Number of partial-product passes (input-channel slabs).
    pub p: usize,
    /// Number of IFM-refetch rounds (OFM batches).
    pub z: usize,
    /// IFMs consumed per slab on the compute path.
    pub slab_ifms: usize,
    /// OFM channels produced per batch.
    pub ofm_batch: usize,
    /// True when this layer runs on TULIP-PEs (vs MACs).
    pub on_pes: bool,
}

impl Tiling {
    /// The paper's P×Z refetch-pressure metric (Table III).
    pub fn refetch_pressure(&self) -> usize {
        self.p * self.z
    }
}

/// Compute the tiling for a layer (Table III logic).
pub fn tiling(layer: &Layer, cfg: &ArchConfig) -> Tiling {
    let on_pes = cfg.kind == ArchKind::Tulip && layer.is_binary() && cfg.num_pes > 0;
    if layer.is_fc() {
        // FC layers stream weights; the "batch" is the unit count and P is
        // a single pass (activations fit on-chip).
        let units = if on_pes { cfg.num_pes } else { cfg.num_macs };
        return Tiling {
            p: 1,
            z: layer.z2.div_ceil(units),
            slab_ifms: layer.z1,
            ofm_batch: units,
            on_pes,
        };
    }
    if on_pes {
        // TULIP-PE path: 32-IFM slabs, 256-OFM batches.
        let slab = cfg.onchip_ifms;
        Tiling {
            p: layer.z1.div_ceil(slab),
            z: layer.z2.div_ceil(cfg.num_pes),
            slab_ifms: slab,
            ofm_batch: cfg.num_pes,
            on_pes,
        }
    } else {
        // MAC path (YodaNN all layers; TULIP integer layers): dual-IFM
        // fetch for k ≤ 5 doubles the slab.
        let slab = if layer.k <= 5 { 2 * cfg.onchip_ifms } else { cfg.onchip_ifms };
        Tiling {
            p: layer.z1.div_ceil(slab),
            z: layer.z2.div_ceil(cfg.num_macs),
            slab_ifms: slab,
            ofm_batch: cfg.num_macs,
            on_pes,
        }
    }
}

/// One row of Table III.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Layer name.
    pub layer: String,
    /// "Binary" or "Integer".
    pub kind: &'static str,
    /// Image partitions (§V-C).
    pub parts: usize,
    /// YodaNN tiling decision.
    pub yodann: Tiling,
    /// TULIP tiling decision.
    pub tulip: Tiling,
}

/// Regenerate Table III for a network's conv layers.
pub fn table3(net: &crate::bnn::Network) -> Vec<Table3Row> {
    let tulip = ArchConfig::tulip();
    let yodann = ArchConfig::yodann();
    net.conv_layers()
        .map(|l| Table3Row {
            layer: l.name.clone(),
            kind: if l.is_binary() { "Binary" } else { "Integer" },
            parts: l.image_parts,
            yodann: tiling(l, &yodann),
            tulip: tiling(l, &tulip),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::alexnet;

    /// Table III, verbatim: P and Z per AlexNet conv layer for both
    /// architectures.
    #[test]
    fn table3_alexnet_matches_paper() {
        let rows = table3(&alexnet());
        // (layer, yodann (P, Z), tulip (P, Z)) from the paper.
        let expect = [
            ("conv1", (1, 3), (1, 3)),
            ("conv2", (2, 8), (2, 8)),
            ("conv3", (4, 12), (8, 2)),
            ("conv4", (6, 12), (12, 2)),
            ("conv5", (6, 8), (12, 1)),
        ];
        for (row, (name, (yp, yz), (tp, tz))) in rows.iter().zip(expect) {
            assert_eq!(row.layer, name);
            assert_eq!((row.yodann.p, row.yodann.z), (yp, yz), "{name} yodann");
            assert_eq!((row.tulip.p, row.tulip.z), (tp, tz), "{name} tulip");
        }
        // Paper: "3X to 4X improvement in overall input-refetch (P×Z)" for
        // binary layers.
        for row in &rows[2..] {
            let ratio = row.yodann.refetch_pressure() as f64 / row.tulip.refetch_pressure() as f64;
            assert!((3.0..=4.5).contains(&ratio), "{}: {ratio}", row.layer);
        }
    }

    /// Integer layers tile identically on both designs (both use MACs).
    #[test]
    fn integer_layers_identical() {
        let rows = table3(&alexnet());
        for row in &rows[..2] {
            assert_eq!(
                (row.yodann.p, row.yodann.z),
                (row.tulip.p, row.tulip.z),
                "{}",
                row.layer
            );
            assert!(!row.tulip.on_pes);
        }
    }

    #[test]
    fn fc_tiling() {
        let net = crate::bnn::binarynet_cifar10();
        let fc = &net.layers[6]; // 8192 → 1024
        let t = tiling(fc, &ArchConfig::tulip());
        assert!(t.on_pes);
        assert_eq!(t.z, 4); // 1024/256
        let y = tiling(fc, &ArchConfig::yodann());
        assert_eq!(y.z, 32); // 1024/32
    }

    #[test]
    fn parts_column() {
        let rows = table3(&alexnet());
        assert_eq!(rows[0].parts, 4);
        assert!(rows[1..].iter().all(|r| r.parts == 1));
    }
}

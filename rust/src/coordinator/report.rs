//! Paper-table report generation: Tables II, IV and V in the exact row
//! format the paper prints, from the analytic model.

use super::exec::{Aggregate, NetworkPerf};
use crate::baseline::MacUnit;
use crate::bnn::Network;
use crate::config::ArchConfig;
use crate::coordinator::exec::pe_node_cost;
use crate::energy::{calib, Activity, EnergyModel};
use crate::scheduler::seqgen::SequenceGenerator;

/// Table II: single-PE comparison for the 288-input neuron (3×3 × 32 IFMs).
#[derive(Debug, Clone, Copy)]
pub struct Table2 {
    /// YodaNN MAC area (paper Table II).
    pub mac_area_um2: f64,
    /// TULIP-PE area (paper Table II).
    pub pe_area_um2: f64,
    /// MAC average power over the window.
    pub mac_power_mw: f64,
    /// PE average power over the node run, from the energy model.
    pub pe_power_mw: f64,
    /// MAC cycles for the 288-input window.
    pub mac_cycles: u64,
    /// PE cycles for the 288-input node.
    pub pe_cycles: u64,
    /// Clock period, nanoseconds.
    pub period_ns: f64,
}

impl Table2 {
    /// Compute the table from the calibrated models.
    pub fn compute() -> Self {
        let mac = MacUnit::yodann();
        let mut sg = SequenceGenerator::new();
        let node = pe_node_cost(&mut sg, 288, 288);
        // Average PE power over the node execution, from the energy model.
        let act = Activity {
            pe_neuron_evals: node.neuron_evals,
            pe_reg_accesses: node.reg_accesses,
            pe_gated_neuron_cycles: node.cycles * 4 - node.neuron_evals,
            total_cycles: node.cycles,
            ..Default::default()
        };
        let m = EnergyModel::default();
        Table2 {
            mac_area_um2: calib::MAC_AREA_UM2,
            pe_area_um2: calib::PE_AREA_UM2,
            mac_power_mw: calib::MAC_POWER_MW,
            pe_power_mw: m.avg_power_mw(&act),
            mac_cycles: mac.window_cycles(3, 32),
            pe_cycles: node.cycles,
            period_ns: calib::CLOCK_NS,
        }
    }

    /// MAC latency in nanoseconds.
    pub fn mac_time_ns(&self) -> f64 {
        self.mac_cycles as f64 * self.period_ns
    }

    /// PE latency in nanoseconds.
    pub fn pe_time_ns(&self) -> f64 {
        self.pe_cycles as f64 * self.period_ns
    }

    /// Power–delay-product advantage of the TULIP-PE (paper: 2.27×).
    pub fn pdp_ratio(&self) -> f64 {
        (self.mac_power_mw * self.mac_time_ns()) / (self.pe_power_mw * self.pe_time_ns())
    }

    /// Paper-format rows (metric, MAC, PE, ratio).
    pub fn rows(&self) -> Vec<Vec<String>> {
        let r = |b: f64, t: f64| format!("{:.2}", b / t);
        vec![
            vec![
                "Area(um^2)".into(),
                format!("{:.3e}", self.mac_area_um2),
                format!("{:.3e}", self.pe_area_um2),
                r(self.mac_area_um2, self.pe_area_um2),
            ],
            vec![
                "Power(mW)".into(),
                format!("{:.2}", self.mac_power_mw),
                format!("{:.3}", self.pe_power_mw),
                r(self.mac_power_mw, self.pe_power_mw),
            ],
            vec![
                "Cycles".into(),
                self.mac_cycles.to_string(),
                self.pe_cycles.to_string(),
                r(self.mac_cycles as f64, self.pe_cycles as f64),
            ],
            vec![
                "Time period(ns)".into(),
                format!("{}", self.period_ns),
                format!("{}", self.period_ns),
                "1".into(),
            ],
            vec![
                "Time(ns)".into(),
                format!("{:.0}", self.mac_time_ns()),
                format!("{:.0}", self.pe_time_ns()),
                r(self.mac_time_ns(), self.pe_time_ns()),
            ],
        ]
    }
}

/// One side-by-side network comparison (a column pair of Table IV/V).
#[derive(Debug, Clone)]
pub struct Comparison {
    /// Network name.
    pub network: String,
    /// Dataset label.
    pub dataset: String,
    /// YodaNN-side aggregate.
    pub yodann: Aggregate,
    /// TULIP-side aggregate.
    pub tulip: Aggregate,
}

impl Comparison {
    /// Run both architecture models over `net` and aggregate at the given
    /// scope (`conv_only` = Table IV, otherwise Table V).
    pub fn run(net: &Network, conv_only: bool) -> Self {
        let t = NetworkPerf::model(net, &ArchConfig::tulip());
        let y = NetworkPerf::model(net, &ArchConfig::yodann());
        let pick =
            |p: &NetworkPerf| if conv_only { p.conv_aggregate() } else { p.total_aggregate() };
        Comparison {
            network: net.name.clone(),
            dataset: net.dataset.clone(),
            yodann: pick(&y),
            tulip: pick(&t),
        }
    }

    /// Energy-efficiency improvement (the paper's headline ~3× conv,
    /// 2.4–2.7× end-to-end).
    pub fn efficiency_gain(&self) -> f64 {
        self.tulip.tops_per_w / self.yodann.tops_per_w
    }

    /// Paper-format rows: Op(MOp), Perf(GOp/s), Energy(uJ), Time(ms),
    /// En.Eff(TOp/s/W) with the TULIP (X) column.
    pub fn rows(&self) -> Vec<Vec<String>> {
        let x = |t: f64, y: f64| format!("({:.1})", t / y);
        vec![
            vec![
                "Op.(MOp)".into(),
                format!("{:.0}", self.yodann.mops),
                format!("{:.0} {}", self.tulip.mops, x(self.tulip.mops, self.yodann.mops)),
            ],
            vec![
                "Perf.(GOp/s)".into(),
                format!("{:.1}", self.yodann.gops),
                format!("{:.1} {}", self.tulip.gops, x(self.tulip.gops, self.yodann.gops)),
            ],
            vec![
                "Energy(uJ)".into(),
                format!("{:.1}", self.yodann.energy_uj),
                format!(
                    "{:.1} {}",
                    self.tulip.energy_uj,
                    x(self.yodann.energy_uj, self.tulip.energy_uj)
                ),
            ],
            vec![
                "Time(ms)".into(),
                format!("{:.1}", self.yodann.time_ms),
                format!("{:.1} {}", self.tulip.time_ms, x(self.yodann.time_ms, self.tulip.time_ms)),
            ],
            vec![
                "En.Eff.(TOp/s/W)".into(),
                format!("{:.1}", self.yodann.tops_per_w),
                format!("{:.1} {}", self.tulip.tops_per_w, x(self.efficiency_gain(), 1.0)),
            ],
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::binarynet_cifar10;

    #[test]
    fn table2_anchors() {
        let t = Table2::compute();
        assert_eq!(t.mac_cycles, 17);
        assert!((t.mac_time_ns() - 39.1).abs() < 0.2);
        // PE average power: our per-event energies are calibrated to the
        // paper's Table IV/V totals, which prices the node run below Table
        // II's 0.12 mW (the two tables are mutually inconsistent by ~2x —
        // see energy::calib and EXPERIMENTS.md §Table II).
        assert!(t.pe_power_mw > 0.015 && t.pe_power_mw < 0.2, "{}", t.pe_power_mw);
        // PDP advantage: same direction as the paper's 2.27x, larger
        // magnitude under the Table IV/V calibration.
        assert!(t.pdp_ratio() > 1.5, "pdp {}", t.pdp_ratio());
        assert_eq!(t.rows().len(), 5);
    }

    #[test]
    fn comparison_runs_and_reports() {
        let net = binarynet_cifar10();
        let c = Comparison::run(&net, true);
        assert!(c.efficiency_gain() > 1.5);
        assert_eq!(c.rows().len(), 5);
        // Op counts identical across architectures by construction.
        assert_eq!(c.yodann.mops, c.tulip.mops);
    }
}

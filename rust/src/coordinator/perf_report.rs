//! Machine-readable performance reports for benchmark trajectories.
//!
//! A [`PerfReport`] freezes one batch run into a comparable artifact:
//! host throughput, simulated cycles and energy, a per-layer cycle/energy
//! breakdown (the shape of the paper's Tables IV–V, but measured from the
//! bit-true engine instead of the analytic model), per-PE utilization,
//! program-cache effectiveness and per-worker timing. The JSON encoder is
//! hand-rolled (the vendored dependency set has no serde); the schema is
//! documented in the repository README under *Observability*.
//!
//! ```
//! use tulip::bnn::tensor::BitTensor;
//! use tulip::bnn::{tiny_bnn, Model};
//! use tulip::coordinator::{BatchExecutor, BatchRequest, PerfReport};
//!
//! let model = Model::random(tiny_bnn(8, 4, 3), 1);
//! let exec = BatchExecutor::for_model(&model)?.with_array(1, 4);
//! let req = BatchRequest::new(vec![BitTensor::random(8, 8, 4, 2)]);
//! let result = exec.run(&req)?;
//! let report = PerfReport::from_batch(&exec, &result);
//! let json = report.to_json();
//! assert!(json.contains("\"schema\": \"tulip.perf_report/v1\""));
//! assert_eq!(report.layers.len(), 3); // conv+pool, fc, fc
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::coordinator::batch::{BatchExecutor, BatchResult, WorkerSummary};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::metrics::{HistogramSnapshot, MetricsSnapshot};
use crate::pe::PeStats;
use crate::scheduler::CacheStats;
use crate::serve::ServeStats;
use crate::sim::cycle::LayerObs;
use crate::util::bench::print_table;
use crate::Result;
use std::path::Path;
use std::time::Duration;

/// One layer's row of a [`PerfReport`]: cycles, share, energy and
/// utilization, merged across every image of the batch.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerReport {
    /// Layer name from the network description.
    pub name: String,
    /// `"conv"`, `"conv+pool"` or `"fc"`.
    pub kind: String,
    /// Lockstep chip cycles spent in this layer across the batch.
    pub cycles: u64,
    /// `cycles` as a fraction of the batch total (0 when the batch is
    /// empty).
    pub cycle_share: f64,
    /// PE energy attributable to this layer, picojoules.
    pub energy_pj: f64,
    /// Neuron utilization within this layer (see
    /// [`PeStats::utilization`](crate::pe::PeStats::utilization)).
    pub utilization: f64,
    /// Neuron evaluations in this layer across the batch.
    pub neuron_evals: u64,
}

/// One PE's row of a [`PerfReport`] (array-flattened index order).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeReport {
    /// Array-flattened PE index.
    pub index: usize,
    /// Neuron evaluations on this PE across the batch.
    pub neuron_evals: u64,
    /// Gated (idle) neuron-cycles on this PE across the batch.
    pub gated_neuron_cycles: u64,
    /// This PE's utilization: `evals / (evals + gated)`.
    pub utilization: f64,
}

/// A frozen, machine-readable report of one batch run. Build with
/// [`PerfReport::from_batch`], serialize with [`PerfReport::to_json`] /
/// [`PerfReport::write_json`], or pretty-print with
/// [`PerfReport::print_summary`].
#[derive(Debug, Clone, PartialEq)]
pub struct PerfReport {
    /// Network name.
    pub network: String,
    /// Dataset name from the network description.
    pub dataset: String,
    /// Execution engine that produced the numbers (`"scalar"` or
    /// `"bit_sliced"` — see
    /// [`ForwardEngine`](crate::coordinator::ForwardEngine)).
    pub engine: String,
    /// Number of images in the batch.
    pub batch: usize,
    /// Host wall-clock time for the batch, milliseconds.
    pub wall_ms: f64,
    /// Host-side simulator throughput.
    pub images_per_sec: f64,
    /// Simulated chip cycles summed over the batch.
    pub total_cycles: u64,
    /// Simulated on-chip latency per image, µs at the paper's clock.
    pub simulated_us_per_image: f64,
    /// Batch energy breakdown at the calibrated model.
    pub energy: EnergyBreakdown,
    /// Per-layer breakdown (sums to the batch totals exactly).
    pub layers: Vec<LayerReport>,
    /// Per-PE activity and utilization.
    pub pes: Vec<PeReport>,
    /// Program-cache effectiveness at report time.
    pub cache: CacheStats,
    /// Per-rayon-worker image counts and busy time.
    pub workers: Vec<WorkerSummary>,
    /// Optional embedded registry snapshot (see
    /// [`PerfReport::with_metrics`]).
    pub metrics: Option<MetricsSnapshot>,
    /// Optional serving-layer accounting (see [`PerfReport::with_serve`]);
    /// present on reports emitted by a draining `tulip serve`.
    pub serve: Option<ServeStats>,
}

/// Raw aggregates for building a [`PerfReport`] without a single
/// [`BatchResult`] in hand — the serve drain path accumulates these across
/// every micro-batch of a server's lifetime.
#[derive(Debug, Clone, Default)]
pub struct ReportParts {
    /// Total images executed.
    pub batch: usize,
    /// Summed engine wall time.
    pub wall: Duration,
    /// Summed simulated chip cycles.
    pub cycles: u64,
    /// Summed PE activity.
    pub stats: PeStats,
    /// Per-layer breakdown (merged; partitions `cycles` exactly).
    pub layers: Vec<LayerObs>,
    /// Per-PE activity (merged, array-flattened index order).
    pub per_pe: Vec<PeStats>,
    /// Per-worker accounting, sorted by worker index.
    pub workers: Vec<WorkerSummary>,
}

impl ReportParts {
    /// The parts of one batch result (what [`PerfReport::from_batch`]
    /// feeds through [`PerfReport::from_parts`]).
    pub fn of_batch(result: &BatchResult) -> Self {
        ReportParts {
            batch: result.images.len(),
            wall: result.wall,
            cycles: result.cycles,
            stats: result.stats,
            layers: result.per_layer(),
            per_pe: result.per_pe(),
            workers: result.worker_summaries(),
        }
    }
}

impl PerfReport {
    /// Freeze `result` (produced by `exec`) into a report. Per-layer
    /// energy prices each layer's activity delta at the default energy
    /// model, so Σ layer energy equals the batch PE energy.
    pub fn from_batch(exec: &BatchExecutor, result: &BatchResult) -> Self {
        Self::from_parts(exec, ReportParts::of_batch(result))
    }

    /// Build a report from raw aggregates (the serve drain path merges
    /// many micro-batches into one [`ReportParts`]).
    pub fn from_parts(exec: &BatchExecutor, parts: ReportParts) -> Self {
        let model = EnergyModel::default();
        let layers: Vec<LayerReport> = parts
            .layers
            .iter()
            .map(|l| LayerReport {
                name: l.name.clone(),
                kind: l.kind.to_string(),
                cycles: l.cycles,
                cycle_share: if parts.cycles == 0 {
                    0.0
                } else {
                    l.cycles as f64 / parts.cycles as f64
                },
                energy_pj: model.energy(&l.stats.activity(l.cycles)).total_pj(),
                utilization: l.utilization(),
                neuron_evals: l.stats.neuron_evals,
            })
            .collect();
        let pes: Vec<PeReport> = parts
            .per_pe
            .iter()
            .enumerate()
            .map(|(index, s)| PeReport {
                index,
                neuron_evals: s.neuron_evals,
                gated_neuron_cycles: s.gated_neuron_cycles,
                utilization: s.utilization(),
            })
            .collect();
        let wall_s = parts.wall.as_secs_f64();
        let net = exec.network();
        PerfReport {
            network: net.name.clone(),
            dataset: net.dataset.clone(),
            engine: exec.engine().name().to_string(),
            batch: parts.batch,
            wall_ms: wall_s * 1e3,
            images_per_sec: if wall_s > 0.0 { parts.batch as f64 / wall_s } else { 0.0 },
            total_cycles: parts.cycles,
            simulated_us_per_image: if parts.batch == 0 {
                0.0
            } else {
                parts.cycles as f64 / parts.batch as f64 * crate::energy::calib::CLOCK_NS * 1e-3
            },
            energy: model.energy(&parts.stats.activity(parts.cycles)),
            layers,
            pes,
            cache: exec.cache_handle().snapshot(),
            workers: parts.workers,
            metrics: None,
            serve: None,
        }
    }

    /// Embed a registry snapshot under the report's `metrics` key.
    pub fn with_metrics(mut self, snapshot: MetricsSnapshot) -> Self {
        self.metrics = Some(snapshot);
        self
    }

    /// Embed serving-layer accounting under the report's `serve` key.
    pub fn with_serve(mut self, serve: ServeStats) -> Self {
        self.serve = Some(serve);
        self
    }

    /// Total energy per classified image, picojoules — the paper's
    /// headline efficiency metric (0 for an empty batch).
    pub fn energy_per_classification_pj(&self) -> f64 {
        if self.batch == 0 {
            0.0
        } else {
            self.energy.total_pj() / self.batch as f64
        }
    }

    /// Mean PE utilization across the array (0 when there are no PEs).
    pub fn mean_pe_utilization(&self) -> f64 {
        if self.pes.is_empty() {
            return 0.0;
        }
        self.pes.iter().map(|p| p.utilization).sum::<f64>() / self.pes.len() as f64
    }

    /// Serialize to the `tulip.perf_report/v1` JSON schema (see README).
    /// Non-finite floats serialize as `0` so the output is always valid
    /// JSON.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str("  \"schema\": \"tulip.perf_report/v1\",\n");
        s.push_str(&format!("  \"network\": {},\n", json_str(&self.network)));
        s.push_str(&format!("  \"dataset\": {},\n", json_str(&self.dataset)));
        s.push_str(&format!("  \"engine\": {},\n", json_str(&self.engine)));
        s.push_str(&format!("  \"batch\": {},\n", self.batch));
        s.push_str(&format!(
            "  \"host\": {{\"wall_ms\": {}, \"images_per_sec\": {}}},\n",
            json_f64(self.wall_ms),
            json_f64(self.images_per_sec)
        ));
        s.push_str(&format!(
            "  \"simulated\": {{\"total_cycles\": {}, \"us_per_image\": {}}},\n",
            self.total_cycles,
            json_f64(self.simulated_us_per_image)
        ));
        s.push_str(&format!(
            "  \"energy_pj\": {{\"pe\": {}, \"mac\": {}, \"memory\": {}, \"xnor\": {}, \
             \"total\": {}, \"per_classification\": {}}},\n",
            json_f64(self.energy.pe_pj),
            json_f64(self.energy.mac_pj),
            json_f64(self.energy.memory_pj),
            json_f64(self.energy.xnor_pj),
            json_f64(self.energy.total_pj()),
            json_f64(self.energy_per_classification_pj())
        ));
        s.push_str("  \"layers\": [\n");
        for (i, l) in self.layers.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {}, \"kind\": {}, \"cycles\": {}, \"cycle_share\": {}, \
                 \"energy_pj\": {}, \"utilization\": {}, \"neuron_evals\": {}}}{}\n",
                json_str(&l.name),
                json_str(&l.kind),
                l.cycles,
                json_f64(l.cycle_share),
                json_f64(l.energy_pj),
                json_f64(l.utilization),
                l.neuron_evals,
                comma(i, self.layers.len())
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"pes\": [\n");
        for (i, p) in self.pes.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"index\": {}, \"neuron_evals\": {}, \"gated_neuron_cycles\": {}, \
                 \"utilization\": {}}}{}\n",
                p.index,
                p.neuron_evals,
                p.gated_neuron_cycles,
                json_f64(p.utilization),
                comma(i, self.pes.len())
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"cache\": {{\"hits\": {}, \"misses\": {}, \"entries\": {}, \"hit_rate\": {}, \
             \"planning_ms\": {}}},\n",
            self.cache.hits,
            self.cache.misses,
            self.cache.entries,
            json_f64(self.cache.hit_rate()),
            json_f64(self.cache.planning_ms())
        ));
        s.push_str("  \"workers\": [\n");
        for (i, w) in self.workers.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"worker\": {}, \"images\": {}, \"busy_ms\": {}}}{}\n",
                w.worker,
                w.images,
                json_f64(w.busy_ns as f64 * 1e-6),
                comma(i, self.workers.len())
            ));
        }
        s.push_str("  ]");
        if let Some(sv) = &self.serve {
            s.push_str(&format!(
                ",\n  \"serve\": {{\n    \"admitted\": {}, \"rejected\": {}, \"shed\": {}, \
                 \"completed\": {}, \"failed\": {},\n    \"batch_occupancy\": {},\n    \
                 \"latency_us\": {{\"queue\": {}, \"batch\": {}, \"total\": {}}}\n  }}",
                sv.admitted,
                sv.rejected,
                sv.shed,
                sv.completed,
                sv.failed,
                hist_json(&sv.occupancy),
                hist_json(&sv.queue_us),
                hist_json(&sv.batch_us),
                hist_json(&sv.total_us)
            ));
        }
        if let Some(m) = &self.metrics {
            s.push_str(",\n  \"metrics\": ");
            s.push_str(&snapshot_json(m, "  "));
        }
        s.push_str("\n}\n");
        s
    }

    /// Write the JSON report to `path` (the `--perf-out` implementation of
    /// the example and bench binaries).
    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json())
            .map_err(|e| anyhow::anyhow!("writing perf report {}: {e}", path.as_ref().display()))
    }

    /// Pretty-print the report: per-layer table, cache/worker lines, and
    /// the headline throughput and energy numbers.
    pub fn print_summary(&self) {
        let rows: Vec<Vec<String>> = self
            .layers
            .iter()
            .map(|l| {
                vec![
                    format!("{} ({})", l.name, l.kind),
                    l.cycles.to_string(),
                    format!("{:.1}%", l.cycle_share * 100.0),
                    format!("{:.1}", l.energy_pj * 1e-3),
                    format!("{:.1}%", l.utilization * 100.0),
                ]
            })
            .collect();
        print_table(
            &format!(
                "PerfReport: {} / {} (batch {}, {} engine)",
                self.network, self.dataset, self.batch, self.engine
            ),
            &["layer", "cycles", "share", "energy (nJ)", "util"],
            &rows,
        );
        println!(
            "host: {:.1} ms wall, {:.1} images/s | simulated: {} cycles, {:.2} us/image",
            self.wall_ms, self.images_per_sec, self.total_cycles, self.simulated_us_per_image
        );
        println!(
            "energy: {:.2} uJ total, {:.1} pJ/classification ({:.1} pe / {:.1} mac / {:.1} mem \
             / {:.1} xnor pJ)",
            self.energy.total_uj(),
            self.energy_per_classification_pj(),
            self.energy.pe_pj,
            self.energy.mac_pj,
            self.energy.memory_pj,
            self.energy.xnor_pj
        );
        println!(
            "cache: {} hits / {} misses ({:.1}% hit rate), {} programs, {:.2} ms planning",
            self.cache.hits,
            self.cache.misses,
            self.cache.hit_rate() * 100.0,
            self.cache.entries,
            self.cache.planning_ms()
        );
        println!(
            "pe utilization: {:.1}% mean across {} PEs",
            self.mean_pe_utilization() * 100.0,
            self.pes.len()
        );
        for w in &self.workers {
            println!(
                "worker {:>2}: {:>4} images, {:.1} ms busy",
                w.worker,
                w.images,
                w.busy_ns as f64 * 1e-6
            );
        }
        if let Some(sv) = &self.serve {
            println!(
                "serve: {} admitted = {} completed + {} shed + {} failed ({} rejected at admission)",
                sv.admitted, sv.completed, sv.shed, sv.failed, sv.rejected
            );
            println!(
                "serve: occupancy mean {:.1}/batch (max {}), total latency p50 {} us / p99 {} us",
                sv.occupancy.mean(),
                sv.occupancy.max,
                sv.total_us.quantile(0.5),
                sv.total_us.quantile(0.99)
            );
        }
    }
}

/// `","` between array elements, nothing after the last.
fn comma(i: usize, len: usize) -> &'static str {
    if i + 1 == len {
        ""
    } else {
        ","
    }
}

/// JSON string literal with escaping.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Histogram summary object: exact count/sum/min/max plus bucket-estimated
/// p50/p99 (shared by the `serve` section and embedded snapshots).
fn hist_json(h: &HistogramSnapshot) -> String {
    format!(
        "{{\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \"mean\": {}, \"p50\": {}, \
         \"p99\": {}}}",
        h.count,
        h.sum,
        h.min,
        h.max,
        json_f64(h.mean()),
        h.quantile(0.5),
        h.quantile(0.99)
    )
}

/// JSON number: non-finite floats become `0` (JSON has no NaN/Infinity).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// Serialize a registry snapshot (counters/gauges as objects, histograms
/// with their summary statistics).
fn snapshot_json(m: &MetricsSnapshot, indent: &str) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("{indent}  \"counters\": {{"));
    for (i, (k, v)) in m.counters.iter().enumerate() {
        s.push_str(&format!("{}{}: {}", comma_lead(i), json_str(k), v));
    }
    s.push_str("},\n");
    s.push_str(&format!("{indent}  \"gauges\": {{"));
    for (i, (k, v)) in m.gauges.iter().enumerate() {
        s.push_str(&format!("{}{}: {}", comma_lead(i), json_str(k), json_f64(*v)));
    }
    s.push_str("},\n");
    s.push_str(&format!("{indent}  \"histograms\": {{"));
    for (i, (k, h)) in m.histograms.iter().enumerate() {
        s.push_str(&format!("{}{}: {}", comma_lead(i), json_str(k), hist_json(h)));
    }
    s.push_str("}\n");
    s.push_str(&format!("{indent}}}"));
    s
}

/// `", "` before every element but the first.
fn comma_lead(i: usize) -> &'static str {
    if i == 0 {
        ""
    } else {
        ", "
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::tensor::BitTensor;
    use crate::bnn::{tiny_bnn, Model};
    use crate::coordinator::{BatchExecutor, BatchRequest};
    use crate::metrics::MetricsRegistry;

    fn tiny_report() -> PerfReport {
        let model = Model::random(tiny_bnn(8, 4, 3), 60);
        let exec = BatchExecutor::for_model(&model).unwrap().with_array(1, 4);
        let req = BatchRequest::new((0..3).map(|i| BitTensor::random(8, 8, 4, i)).collect());
        let result = exec.run(&req).unwrap();
        PerfReport::from_batch(&exec, &result)
    }

    #[test]
    fn report_partitions_totals() {
        let r = tiny_report();
        assert_eq!(r.batch, 3);
        let layer_cycles: u64 = r.layers.iter().map(|l| l.cycles).sum();
        assert_eq!(layer_cycles, r.total_cycles, "layer cycles partition the total");
        let share: f64 = r.layers.iter().map(|l| l.cycle_share).sum();
        assert!((share - 1.0).abs() < 1e-9);
        // Per-layer PE energy sums to the batch PE energy (same counters,
        // same model — only the grouping differs).
        let layer_pj: f64 = r.layers.iter().map(|l| l.energy_pj).sum();
        assert!((layer_pj - r.energy.pe_pj).abs() <= 1e-9 * r.energy.pe_pj.max(1.0));
        assert!(r.layers.iter().all(|l| (0.0..=1.0).contains(&l.utilization)));
        assert!(r.pes.iter().all(|p| (0.0..=1.0).contains(&p.utilization)));
        assert!(r.mean_pe_utilization() > 0.0);
        assert!(!r.workers.is_empty());
    }

    #[test]
    fn json_is_well_formed_and_complete() {
        let reg = MetricsRegistry::new();
        reg.counter("test.count").add(3);
        reg.histogram("test.lat").observe(42);
        let r = tiny_report().with_metrics(reg.snapshot());
        let json = r.to_json();
        const KEYS: &str = "schema network engine host simulated energy_pj per_classification \
                            layers pes cache hit_rate workers metrics utilization planning_ms";
        for key in KEYS.split_whitespace() {
            assert!(json.contains(&format!("\"{key}\"")), "missing {key} in:\n{json}");
        }
        assert!(!json.contains("NaN") && !json.contains("inf"), "non-finite leaked");
        let depth = json.chars().fold(0i64, |d, c| match c {
            '{' | '[' => d + 1,
            '}' | ']' => d - 1,
            _ => d,
        });
        assert_eq!(depth, 0, "unbalanced brackets");
    }

    #[test]
    fn write_json_round_trips_to_disk() {
        let r = tiny_report();
        let path = std::env::temp_dir().join("tulip_perf_report_test.json");
        r.write_json(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, r.to_json());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn string_escaping() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_f64(f64::NAN), "0");
        assert_eq!(json_f64(1.5), "1.5");
    }

    #[test]
    fn summary_does_not_panic() {
        tiny_report().print_summary();
    }
}

//! The TULIP-PE: a fully connected network of four `[2,1,1,1;T]` threshold
//! cells with 16-bit local registers and a shared-bus mux fabric (§IV-A,
//! Fig. 3), executed one control word per clock.
//!
//! The executor is **bit-true and cycle-accurate**: every quantity the
//! energy model consumes (neuron evaluations, gated cycles, register
//! accesses, cycle count) is counted here, and every schedule the analytic
//! performance model prices is exactly a `Vec<ControlWord>` that this
//! executor can run — so the perf model and the bit-true model cannot
//! drift apart (asserted by tests in `sim::`).

pub mod isa;
pub mod registers;
pub mod slice;

pub use isa::{ControlWord, NeuronCtl, RegWrite, Src, WSrc, NUM_NEURONS, NUM_REGS, REG_BITS};
pub use registers::RegisterFile;

use crate::neuron::HwNeuron;

/// Activity counters for the energy model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeStats {
    /// Total clock cycles stepped.
    pub cycles: u64,
    /// Neuron evaluations (non-gated neuron-cycles).
    pub neuron_evals: u64,
    /// Gated neuron-cycles (leakage-only).
    pub gated_neuron_cycles: u64,
    /// Register bit-reads.
    pub reg_reads: u64,
    /// Register bit-writes.
    pub reg_writes: u64,
}

impl PeStats {
    /// Merge counters (e.g. across PEs).
    pub fn merge(&mut self, other: &PeStats) {
        self.cycles += other.cycles;
        self.neuron_evals += other.neuron_evals;
        self.gated_neuron_cycles += other.gated_neuron_cycles;
        self.reg_reads += other.reg_reads;
        self.reg_writes += other.reg_writes;
    }

    /// Counter-wise difference `self − earlier`, for per-layer deltas
    /// between two cumulative snapshots of the same array. Saturating, so
    /// a reset between snapshots yields zeros instead of underflowing.
    pub fn delta(&self, earlier: &PeStats) -> PeStats {
        PeStats {
            cycles: self.cycles.saturating_sub(earlier.cycles),
            neuron_evals: self.neuron_evals.saturating_sub(earlier.neuron_evals),
            gated_neuron_cycles: self
                .gated_neuron_cycles
                .saturating_sub(earlier.gated_neuron_cycles),
            reg_reads: self.reg_reads.saturating_sub(earlier.reg_reads),
            reg_writes: self.reg_writes.saturating_sub(earlier.reg_writes),
        }
    }

    /// All counters multiplied by `k` — the activity of running the same
    /// control-flow-determined schedule `k` times. This is how the
    /// bit-sliced engine accounts analytically: measure one unit run
    /// (see [`CachedProgram::unit_stats`]), then scale by the number of
    /// modelled lane-runs.
    ///
    /// [`CachedProgram::unit_stats`]: crate::scheduler::seqgen::CachedProgram::unit_stats
    pub fn scaled(&self, k: u64) -> PeStats {
        PeStats {
            cycles: self.cycles * k,
            neuron_evals: self.neuron_evals * k,
            gated_neuron_cycles: self.gated_neuron_cycles * k,
            reg_reads: self.reg_reads * k,
            reg_writes: self.reg_writes * k,
        }
    }

    /// Fraction of neuron-cycles doing real work: `evals / (evals +
    /// gated)`. This is the per-PE utilization reported in perf reports
    /// (the paper's energy argument rests on gating idle neurons, §IV-E);
    /// 0 when the PE never clocked.
    pub fn utilization(&self) -> f64 {
        let total = self.neuron_evals + self.gated_neuron_cycles;
        if total == 0 {
            0.0
        } else {
            self.neuron_evals as f64 / total as f64
        }
    }

    /// Map these counters (plus the lockstep cycle count they were
    /// gathered over) into the energy model's [`Activity`] record, pricing
    /// evaluations, gated cycles and register bit-accesses.
    ///
    /// [`Activity`]: crate::energy::Activity
    pub fn activity(&self, cycles: u64) -> crate::energy::Activity {
        crate::energy::Activity {
            pe_neuron_evals: self.neuron_evals,
            pe_gated_neuron_cycles: self.gated_neuron_cycles,
            pe_reg_accesses: self.reg_reads + self.reg_writes,
            total_cycles: cycles,
            ..Default::default()
        }
    }
}

/// One TULIP processing element.
#[derive(Debug, Clone)]
pub struct TulipPe {
    neurons: [HwNeuron; NUM_NEURONS],
    regs: RegisterFile,
    stats: PeStats,
}

impl Default for TulipPe {
    fn default() -> Self {
        Self::new()
    }
}

impl TulipPe {
    /// A fresh PE: all neurons low, registers zeroed, counters at zero.
    pub fn new() -> Self {
        TulipPe {
            neurons: [HwNeuron::new(); NUM_NEURONS],
            regs: RegisterFile::new(),
            stats: PeStats::default(),
        }
    }

    /// Latched output of neuron `k` (0-based; `N1` is `k = 0`).
    pub fn neuron_out(&self, k: usize) -> bool {
        self.neurons[k].output()
    }

    /// Mutable access to the register file (test setup / operand loading —
    /// architecturally this is the path from the XNOR array / input buffers).
    pub fn regs_mut(&mut self) -> &mut RegisterFile {
        &mut self.regs
    }

    /// Read-only view of the register file.
    pub fn regs(&self) -> &RegisterFile {
        &self.regs
    }

    /// Activity counters accumulated since the last reset.
    pub fn stats(&self) -> PeStats {
        self.stats
    }

    /// Zero the activity counters (register contents are left alone).
    pub fn reset_stats(&mut self) {
        self.stats = PeStats::default();
        self.regs.reset_counters();
    }

    /// Resolve a combinational source. `fresh` carries the already-updated
    /// phase-0 outputs (`None` while resolving buses / phase-0 inputs).
    #[inline(always)]
    fn resolve(
        regs: &mut RegisterFile,
        src: Src,
        ext: &[bool],
        old: &[bool; NUM_NEURONS],
        fresh: Option<&[bool; NUM_NEURONS]>,
    ) -> bool {
        match src {
            Src::Zero => false,
            Src::One => true,
            Src::Ext(i) => {
                assert!(i < ext.len(), "ext channel {i} not driven (have {})", ext.len());
                ext[i]
            }
            Src::N(k) => old[k],
            Src::NInv(k) => !old[k],
            Src::NFresh(k) => fresh.expect("fresh read before phase 0 complete")[k],
            Src::NFreshInv(k) => !fresh.expect("fresh read before phase 0 complete")[k],
            Src::Reg { reg, bit } => regs.read(reg, bit),
            Src::RegInv { reg, bit } => !regs.read(reg, bit),
        }
    }

    /// Execute one control word with the given external input bits.
    ///
    /// Cycle semantics (see `isa.rs` module docs):
    /// 1. buses resolve combinationally (registers / old outputs / ext);
    /// 2. phase-0 neurons evaluate and latch;
    /// 3. phase-1 neurons evaluate (may sample fresh phase-0 outputs) and
    ///    latch;
    /// 4. register writes commit (may sample fresh outputs or, via
    ///    [`WSrc::NOld`], the pre-cycle outputs).
    pub fn step(&mut self, cw: &ControlWord, ext: &[bool]) {
        debug_assert!(cw.validate().is_ok(), "invalid control word: {:?}", cw.validate());
        let old: [bool; NUM_NEURONS] = std::array::from_fn(|k| self.neurons[k].output());

        let bus_b = Self::resolve(&mut self.regs, cw.bus_b, ext, &old, None);
        let bus_c = Self::resolve(&mut self.regs, cw.bus_c, ext, &old, None);

        // Phase 0.
        let mut next = old;
        for (k, n) in cw.neurons.iter().enumerate() {
            if n.gated || n.phase != 0 {
                continue;
            }
            let a = Self::resolve(&mut self.regs, n.a, ext, &old, None);
            let d = Self::resolve(&mut self.regs, n.d, ext, &old, None);
            let b = n.b_en && (bus_b ^ n.b_inv);
            let c = n.c_en && (bus_c ^ n.c_inv);
            next[k] = self.neurons[k].clock(a, b, c, d, n.threshold);
            self.stats.neuron_evals += 1;
        }
        let after_p0 = next;

        // Phase 1 (the cascade).
        for (k, n) in cw.neurons.iter().enumerate() {
            if n.gated {
                self.stats.gated_neuron_cycles += 1;
                continue;
            }
            if n.phase == 0 {
                continue;
            }
            let a = Self::resolve(&mut self.regs, n.a, ext, &old, Some(&after_p0));
            let d = Self::resolve(&mut self.regs, n.d, ext, &old, Some(&after_p0));
            let b = n.b_en && (bus_b ^ n.b_inv);
            let c = n.c_en && (bus_c ^ n.c_inv);
            next[k] = self.neurons[k].clock(a, b, c, d, n.threshold);
            self.stats.neuron_evals += 1;
        }

        // Register writes.
        for w in &cw.writes {
            let v = match w.src {
                WSrc::N(k) => next[k],
                WSrc::NInv(k) => !next[k],
                WSrc::NOld(k) => old[k],
                WSrc::Ext(i) => {
                    assert!(i < ext.len(), "ext channel {i} not driven");
                    ext[i]
                }
                WSrc::Reg { reg, bit } => self.regs.read(reg, bit),
                WSrc::Zero => false,
                WSrc::One => true,
            };
            self.regs.write(w.reg, w.bit, v);
        }

        let (r, w) = self.regs.access_counts();
        self.stats.reg_reads = r;
        self.stats.reg_writes = w;
        self.stats.cycles += 1;
    }

    /// Run a schedule. `ext_stream[cycle]` supplies the external input bits
    /// for each cycle (empty slice for cycles with no external operands).
    pub fn run(&mut self, schedule: &[ControlWord], ext_stream: &[Vec<bool>]) {
        static EMPTY: Vec<bool> = Vec::new();
        for (i, cw) in schedule.iter().enumerate() {
            let ext = ext_stream.get(i).unwrap_or(&EMPTY);
            self.step(cw, ext);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A single-cycle full adder: N3 = carry (phase 0), N2 = sum (phase 1,
    /// reads the fresh carry) — the "cascade of two binary neurons" of §III.
    fn fa_word(x: Src, y: Src, cin: Src) -> ControlWord {
        let mut cw = ControlWord::idle();
        cw.bus_b = x;
        cw.bus_c = y;
        // N3 (index 2): carry = maj(x, y, cin) = [b + c + d ≥ 2]
        cw.neurons[2] = NeuronCtl {
            gated: false,
            phase: 0,
            a: Src::Zero,
            b_en: true,
            b_inv: false,
            c_en: true,
            c_inv: false,
            d: cin,
            threshold: 2,
        };
        // N2 (index 1): sum = [2·¬carry + x + y + cin ≥ 3]
        cw.neurons[1] = NeuronCtl {
            gated: false,
            phase: 1,
            a: Src::NFreshInv(2),
            b_en: true,
            b_inv: false,
            c_en: true,
            c_inv: false,
            d: cin,
            threshold: 3,
        };
        cw
    }

    /// Exhaustive: the two-neuron cascade is a full adder.
    #[test]
    fn cascade_full_adder_exhaustive() {
        for m in 0u32..8 {
            let (x, y, cin) = (m & 1 != 0, m & 2 != 0, m & 4 != 0);
            let mut pe = TulipPe::new();
            let cw = fa_word(Src::Ext(0), Src::Ext(1), Src::Ext(2));
            pe.step(&cw, &[x, y, cin]);
            let sum = pe.neuron_out(1);
            let carry = pe.neuron_out(2);
            let total = x as u32 + y as u32 + cin as u32;
            assert_eq!(carry as u32 * 2 + sum as u32, total, "m={m:03b}");
        }
    }

    /// Ripple addition through the carry latch: d = N3's own old output.
    #[test]
    fn ripple_add_via_carry_latch() {
        // 4-bit x = 0b1011 (11), y = 0b0110 (6) → 17 = 0b10001.
        let x = [true, true, false, true];
        let y = [false, true, true, false];
        let mut pe = TulipPe::new();
        let mut sum_bits = Vec::new();
        for i in 0..4 {
            let mut cw =
                fa_word(Src::Ext(0), Src::Ext(1), if i == 0 { Src::Zero } else { Src::N(2) });
            cw.writes = vec![RegWrite { reg: 0, bit: i, src: WSrc::N(1) }];
            pe.step(&cw, &[x[i], y[i]]);
            sum_bits.push(pe.neuron_out(1));
        }
        let carry_out = pe.neuron_out(2);
        let got = sum_bits.iter().enumerate().map(|(i, &b)| (b as u32) << i).sum::<u32>()
            + ((carry_out as u32) << 4);
        assert_eq!(got, 17);
        assert_eq!(pe.regs().peek_field(0, 0, 4), 17 & 0xf);
    }

    #[test]
    fn gated_neuron_holds_and_counts() {
        let mut pe = TulipPe::new();
        let mut cw = ControlWord::idle();
        cw.neurons[0] = NeuronCtl::active(0); // T=0 → latch 1
        pe.step(&cw, &[]);
        assert!(pe.neuron_out(0));
        // Now gate it and try to force 0 — it must hold.
        let cw2 = ControlWord::idle();
        pe.step(&cw2, &[]);
        assert!(pe.neuron_out(0));
        assert_eq!(pe.stats().neuron_evals, 1);
        assert_eq!(pe.stats().gated_neuron_cycles, 3 + 4);
        assert_eq!(pe.stats().cycles, 2);
    }

    #[test]
    fn nold_write_spills_pre_cycle_value() {
        let mut pe = TulipPe::new();
        // Cycle 1: N1 latches 1.
        let mut cw = ControlWord::idle();
        cw.neurons[0] = NeuronCtl::active(0);
        pe.step(&cw, &[]);
        // Cycle 2: N1 latches 0 while its OLD value (1) spills to R2[0].
        let mut cw = ControlWord::idle();
        cw.neurons[0] = NeuronCtl::active(6); // unreachable → 0
        cw.writes = vec![RegWrite { reg: 1, bit: 0, src: WSrc::NOld(0) }];
        pe.step(&cw, &[]);
        assert!(!pe.neuron_out(0));
        assert!(pe.regs().peek(1, 0));
    }

    #[test]
    fn bus_inversion_per_neuron() {
        let mut pe = TulipPe::new();
        let mut cw = ControlWord::idle();
        cw.bus_b = Src::One;
        // N1 takes b inverted (0), N2 takes b straight (1); T = 1 each.
        cw.neurons[0] =
            NeuronCtl { gated: false, b_en: true, b_inv: true, ..NeuronCtl::active(1) };
        cw.neurons[1] = NeuronCtl { gated: false, b_en: true, ..NeuronCtl::active(1) };
        pe.step(&cw, &[]);
        assert!(!pe.neuron_out(0));
        assert!(pe.neuron_out(1));
    }

    #[test]
    fn ext_write_and_reg_copy() {
        let mut pe = TulipPe::new();
        let mut cw = ControlWord::idle();
        cw.writes = vec![RegWrite { reg: 0, bit: 3, src: WSrc::Ext(0) }];
        pe.step(&cw, &[true]);
        assert!(pe.regs().peek(0, 3));
        let mut cw = ControlWord::idle();
        cw.writes = vec![RegWrite { reg: 3, bit: 7, src: WSrc::Reg { reg: 0, bit: 3 } }];
        pe.step(&cw, &[]);
        assert!(pe.regs().peek(3, 7));
    }
}

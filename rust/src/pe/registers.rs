//! The per-neuron 16-bit local registers (§IV-A).
//!
//! "The local registers are constructed using latches. As opposed to global
//! registers, the local registers allow the neurons to access temporarily
//! stored data faster, and also reduce the power consumption per read/write
//! access." Each register is a bank of 16 individually-enabled latches, so
//! distinct bits may be read and written in the same cycle; the executor
//! enforces ≤ 2 bit-writes per register per cycle (see `isa.rs`).

use super::isa::{NUM_REGS, REG_BITS};

/// Latch-based register file: 4 × 16 bits with access counters for the
/// energy model.
#[derive(Debug, Clone, Default)]
pub struct RegisterFile {
    regs: [u16; NUM_REGS],
    reads: u64,
    writes: u64,
}

impl RegisterFile {
    /// An empty register file with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Read one bit (counted).
    #[inline]
    pub fn read(&mut self, reg: usize, bit: usize) -> bool {
        debug_assert!(reg < NUM_REGS && bit < REG_BITS);
        self.reads += 1;
        self.regs[reg] >> bit & 1 != 0
    }

    /// Peek without counting (testing / visualization).
    #[inline]
    pub fn peek(&self, reg: usize, bit: usize) -> bool {
        self.regs[reg] >> bit & 1 != 0
    }

    /// Write one bit (counted).
    #[inline]
    pub fn write(&mut self, reg: usize, bit: usize, v: bool) {
        debug_assert!(reg < NUM_REGS && bit < REG_BITS);
        self.writes += 1;
        if v {
            self.regs[reg] |= 1 << bit;
        } else {
            self.regs[reg] &= !(1 << bit);
        }
    }

    /// Read a `width`-bit little-endian field of register `reg` starting at
    /// `lsb` (not counted — used by tests and the functional checker).
    pub fn peek_field(&self, reg: usize, lsb: usize, width: usize) -> u32 {
        assert!(lsb + width <= REG_BITS);
        (self.regs[reg] as u32 >> lsb) & ((1u32 << width) - 1)
    }

    /// Overwrite a field (test setup).
    pub fn poke_field(&mut self, reg: usize, lsb: usize, width: usize, value: u32) {
        assert!(lsb + width <= REG_BITS, "field out of range");
        assert!(width == 32 || value < (1u32 << width), "value too wide");
        let mask = (((1u32 << width) - 1) << lsb) as u16;
        self.regs[reg] = (self.regs[reg] & !mask) | (((value as u16) << lsb) & mask);
    }

    /// Raw register values.
    pub fn raw(&self) -> [u16; NUM_REGS] {
        self.regs
    }

    /// (reads, writes) access counters.
    pub fn access_counts(&self) -> (u64, u64) {
        (self.reads, self.writes)
    }

    /// Zero the access counters, keeping contents.
    pub fn reset_counters(&mut self) {
        self.reads = 0;
        self.writes = 0;
    }

    /// Clear contents and counters.
    pub fn clear(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_rw_roundtrip() {
        let mut rf = RegisterFile::new();
        rf.write(2, 5, true);
        assert!(rf.read(2, 5));
        assert!(!rf.read(2, 4));
        rf.write(2, 5, false);
        assert!(!rf.peek(2, 5));
        assert_eq!(rf.access_counts(), (2, 2));
    }

    #[test]
    fn field_poke_peek() {
        let mut rf = RegisterFile::new();
        rf.poke_field(1, 3, 5, 0b10110);
        assert_eq!(rf.peek_field(1, 3, 5), 0b10110);
        assert_eq!(rf.peek_field(1, 0, 3), 0);
        // neighbouring bits untouched
        rf.poke_field(1, 0, 3, 0b111);
        assert_eq!(rf.peek_field(1, 3, 5), 0b10110);
    }

    #[test]
    #[should_panic]
    fn field_out_of_range_panics() {
        let mut rf = RegisterFile::new();
        rf.poke_field(0, 10, 8, 0);
    }

    #[test]
    fn counters_reset() {
        let mut rf = RegisterFile::new();
        rf.write(0, 0, true);
        rf.read(0, 0);
        rf.reset_counters();
        assert_eq!(rf.access_counts(), (0, 0));
        assert!(rf.peek(0, 0), "contents survive counter reset");
        rf.clear();
        assert!(!rf.peek(0, 0));
    }
}

//! Bit-sliced, lane-parallel TULIP-PE: 64 lockstep lanes per control word.
//!
//! The paper's §IV-E invariant — one sequence generator broadcasts the
//! *same* control word to every PE each cycle — means that across any set
//! of PEs running a shared program, the control flow is identical and only
//! the data bits differ. [`PeSlice`] exploits that by transposing the
//! layout: every 1-bit quantity of the scalar [`TulipPe`](super::TulipPe)
//! (a neuron latch, a register bit, an external product bit) becomes a
//! `u64` word holding that bit for 64 independent *lanes*, and one step of
//! pure bitwise logic advances all 64 lanes at once. The per-lane semantics
//! are, bit for bit, those of [`TulipPe::step`](super::TulipPe::step) —
//! asserted lane-by-lane by the tests below and end-to-end by
//! `tests/bitslice.rs`.
//!
//! The threshold evaluation `2a + b + c + d ≥ T` of the `[2,1,1,1;T]` cell
//! (§II) becomes one of seven small bitwise formulas, one per reachable
//! threshold — e.g. `T = 2` is `a | (b&c) | (b&d) | (c&d)` ("a alone
//! suffices, or any two of the weight-1 inputs").
//!
//! Activity counters are deliberately absent here: a schedule's per-run
//! activity is control-flow determined (data never changes which neurons
//! evaluate or which register bits are touched), so the lane-parallel
//! engine accounts analytically via
//! [`CachedProgram::unit_stats`](crate::scheduler::seqgen::CachedProgram::unit_stats)
//! instead of counting per step.

use super::isa::{ControlWord, Src, WSrc, NUM_NEURONS, NUM_REGS, REG_BITS};
use crate::scheduler::{ExtSpec, Schedule};

/// Lanes per slice word — the bit width of the host word the simulator
/// packs lanes into.
pub const LANES: usize = 64;

/// All-ones lane word (`true` in every lane).
const ONES: u64 = !0u64;

/// Evaluate the `[2,1,1,1;T]` threshold cell in all 64 lanes at once:
/// bit `j` of the result is `2·a_j + b_j + c_j + d_j ≥ t`.
#[inline(always)]
fn fire(a: u64, b: u64, c: u64, d: u64, t: i32) -> u64 {
    match t {
        t if t <= 0 => ONES,
        1 => a | b | c | d,
        2 => a | (b & c) | (b & d) | (c & d),
        3 => (a & (b | c | d)) | (b & c & d),
        4 => a & ((b & c) | (b & d) | (c & d)),
        5 => a & b & c & d,
        _ => 0,
    }
}

/// 64 lockstep TULIP-PE lanes: neuron latches and register bits held as
/// `u64` words, one bit per lane. Stepping costs one pass of bitwise logic
/// per control word regardless of how many lanes are live; unused lanes
/// simply carry don't-care bits the caller never reads back.
#[derive(Debug, Clone)]
pub struct PeSlice {
    /// Latched neuron outputs, one word per neuron.
    neurons: [u64; NUM_NEURONS],
    /// Register bits: `regs[reg][bit]` is one word across the lanes.
    regs: [[u64; REG_BITS]; NUM_REGS],
}

impl Default for PeSlice {
    fn default() -> Self {
        Self::new()
    }
}

impl PeSlice {
    /// A fresh slice: every lane's neurons low and registers zeroed —
    /// 64 lanes of [`TulipPe::new`](super::TulipPe::new).
    pub fn new() -> Self {
        PeSlice { neurons: [0; NUM_NEURONS], regs: [[0; REG_BITS]; NUM_REGS] }
    }

    /// Reset all lanes to the fresh state.
    pub fn clear(&mut self) {
        *self = Self::new();
    }

    /// Latched outputs of neuron `k`, one bit per lane.
    #[inline]
    pub fn neuron_word(&self, k: usize) -> u64 {
        self.neurons[k]
    }

    /// Register bit `R[reg][bit]`, one bit per lane.
    #[inline]
    pub fn reg_word(&self, reg: usize, bit: usize) -> u64 {
        self.regs[reg][bit]
    }

    /// Read a `width`-bit little-endian register field of a single lane —
    /// the lane-local equivalent of
    /// [`RegisterFile::peek_field`](super::RegisterFile::peek_field).
    pub fn peek_field_lane(&self, reg: usize, lsb: usize, width: usize, lane: usize) -> u32 {
        assert!(lsb + width <= REG_BITS, "field out of range");
        assert!(lane < LANES, "lane out of range");
        let mut v = 0u32;
        for i in 0..width {
            if self.regs[reg][lsb + i] >> lane & 1 != 0 {
                v |= 1 << i;
            }
        }
        v
    }

    /// Resolve a combinational source across all lanes. `fresh` carries the
    /// already-updated phase-0 outputs (`None` while resolving buses and
    /// phase-0 inputs) — same contract as the scalar resolver.
    #[inline(always)]
    fn resolve(
        &self,
        src: Src,
        ext: &[u64],
        old: &[u64; NUM_NEURONS],
        fresh: Option<&[u64; NUM_NEURONS]>,
    ) -> u64 {
        match src {
            Src::Zero => 0,
            Src::One => ONES,
            Src::Ext(i) => {
                assert!(i < ext.len(), "ext channel {i} not driven (have {})", ext.len());
                ext[i]
            }
            Src::N(k) => old[k],
            Src::NInv(k) => !old[k],
            Src::NFresh(k) => fresh.expect("fresh read before phase 0 complete")[k],
            Src::NFreshInv(k) => !fresh.expect("fresh read before phase 0 complete")[k],
            Src::Reg { reg, bit } => self.regs[reg][bit],
            Src::RegInv { reg, bit } => !self.regs[reg][bit],
        }
    }

    /// Execute one control word in all 64 lanes. `ext[i]` carries external
    /// channel `i`, one bit per lane. The per-lane cycle semantics are
    /// exactly [`TulipPe::step`](super::TulipPe::step): buses resolve
    /// first, phase-0 neurons latch, phase-1 neurons may sample fresh
    /// phase-0 outputs, then register writes commit.
    pub fn step(&mut self, cw: &ControlWord, ext: &[u64]) {
        debug_assert!(cw.validate().is_ok(), "invalid control word: {:?}", cw.validate());
        let old = self.neurons;
        let bus_b = self.resolve(cw.bus_b, ext, &old, None);
        let bus_c = self.resolve(cw.bus_c, ext, &old, None);

        // Phase 0. Gated neurons hold (their word stays `old`).
        let mut next = old;
        for (k, n) in cw.neurons.iter().enumerate() {
            if n.gated || n.phase != 0 {
                continue;
            }
            let a = self.resolve(n.a, ext, &old, None);
            let d = self.resolve(n.d, ext, &old, None);
            let b = if n.b_en { bus_b ^ if n.b_inv { ONES } else { 0 } } else { 0 };
            let c = if n.c_en { bus_c ^ if n.c_inv { ONES } else { 0 } } else { 0 };
            next[k] = fire(a, b, c, d, n.threshold);
        }
        let after_p0 = next;

        // Phase 1 (the cascade).
        for (k, n) in cw.neurons.iter().enumerate() {
            if n.gated || n.phase == 0 {
                continue;
            }
            let a = self.resolve(n.a, ext, &old, Some(&after_p0));
            let d = self.resolve(n.d, ext, &old, Some(&after_p0));
            let b = if n.b_en { bus_b ^ if n.b_inv { ONES } else { 0 } } else { 0 };
            let c = if n.c_en { bus_c ^ if n.c_inv { ONES } else { 0 } } else { 0 };
            next[k] = fire(a, b, c, d, n.threshold);
        }
        self.neurons = next;

        // Register writes.
        for w in &cw.writes {
            let v = match w.src {
                WSrc::N(k) => next[k],
                WSrc::NInv(k) => !next[k],
                WSrc::NOld(k) => old[k],
                WSrc::Ext(i) => {
                    assert!(i < ext.len(), "ext channel {i} not driven");
                    ext[i]
                }
                WSrc::Reg { reg, bit } => self.regs[reg][bit],
                WSrc::Zero => 0,
                WSrc::One => ONES,
            };
            self.regs[w.reg][w.bit] = v;
        }
    }

    /// Run a whole schedule, materializing each external channel from
    /// `product_word(i)` — the lane word for product bit `i`. The
    /// lane-parallel analogue of
    /// [`Schedule::run_on`](crate::scheduler::Schedule::run_on); external
    /// rows materialize into a stack buffer, so this loop performs no heap
    /// allocation.
    pub fn run<F>(&mut self, schedule: &Schedule, mut product_word: F)
    where
        F: FnMut(usize) -> u64,
    {
        const MAX_EXT: usize = 8;
        let mut ext_buf = [0u64; MAX_EXT];
        for (word, row) in schedule.words.iter().zip(&schedule.ext_map) {
            debug_assert!(row.len() <= MAX_EXT, "ext row wider than physical channels");
            for (slot, e) in ext_buf.iter_mut().zip(row) {
                *slot = match *e {
                    ExtSpec::Product(i) => product_word(i),
                    ExtSpec::Lit(b) => {
                        if b {
                            ONES
                        } else {
                            0
                        }
                    }
                };
            }
            self.step(word, &ext_buf[..row.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::TulipPe;
    use crate::scheduler::seqgen::{OpDesc, SequenceGenerator};
    use crate::util::Rng;

    /// `fire` equals the arithmetic definition for every input and every
    /// reachable threshold, in every lane position.
    #[test]
    fn fire_matches_arithmetic_exhaustively() {
        for t in -2..9 {
            for m in 0u64..16 {
                let (a, b, c, d) = (m & 1, m >> 1 & 1, m >> 2 & 1, m >> 3 & 1);
                let expect = (2 * a + b + c + d) as i32 >= t;
                // Splat the single-bit case into two distinct lanes.
                for lane in [0usize, 63] {
                    let w = fire(a << lane, b << lane, c << lane, d << lane, t);
                    assert_eq!(w >> lane & 1 != 0, expect, "a{a} b{b} c{c} d{d} t{t}");
                }
            }
        }
    }

    /// Lane-by-lane equivalence with the scalar PE over a real threshold
    /// program on random products: neuron outputs and every register bit
    /// must match in every lane, including ragged upper lanes.
    #[test]
    fn slice_matches_scalar_per_lane() {
        let mut sg = SequenceGenerator::new();
        let prog = sg.program(&OpDesc::ThresholdNode { n: 48, t_popcount: 23 });
        let arity = prog.schedule.product_arity();
        let mut rng = Rng::seed_from_u64(0x51_1CE);
        // One random product vector per lane.
        let lanes: Vec<Vec<bool>> =
            (0..LANES).map(|_| (0..arity).map(|_| rng.gen_bool(0.5)).collect()).collect();
        // Transpose into product words.
        let words: Vec<u64> = (0..arity)
            .map(|p| {
                lanes
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l[p])
                    .fold(0u64, |w, (j, _)| w | 1 << j)
            })
            .collect();
        let mut slice = PeSlice::new();
        slice.run(&prog.schedule, |p| words[p]);
        for (j, products) in lanes.iter().enumerate() {
            let mut pe = TulipPe::new();
            prog.schedule.run_on(&mut pe, products);
            for k in 0..NUM_NEURONS {
                assert_eq!(slice.neuron_word(k) >> j & 1 != 0, pe.neuron_out(k), "lane {j} N{k}");
            }
            for reg in 0..NUM_REGS {
                for bit in 0..REG_BITS {
                    assert_eq!(
                        slice.reg_word(reg, bit) >> j & 1 != 0,
                        pe.regs().peek(reg, bit),
                        "lane {j} R{reg}[{bit}]"
                    );
                }
            }
        }
    }

    /// The register-field readback agrees with the scalar `peek_field` on
    /// the sum-tree output field.
    #[test]
    fn field_readback_matches_scalar() {
        let mut sg = SequenceGenerator::new();
        let prog = sg.program(&OpDesc::SumTree { n: 30 });
        let Some(crate::scheduler::Loc::Reg { reg, lsb, width }) = prog.out_loc else {
            panic!("sum tree leaves its result in a register");
        };
        let arity = prog.schedule.product_arity();
        let mut rng = Rng::seed_from_u64(7);
        let lanes: Vec<Vec<bool>> =
            (0..17).map(|_| (0..arity).map(|_| rng.gen_bool(0.4)).collect()).collect();
        let words: Vec<u64> = (0..arity)
            .map(|p| {
                lanes
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l[p])
                    .fold(0u64, |w, (j, _)| w | 1 << j)
            })
            .collect();
        let mut slice = PeSlice::new();
        slice.run(&prog.schedule, |p| words[p]);
        for (j, products) in lanes.iter().enumerate() {
            let mut pe = TulipPe::new();
            prog.schedule.run_on(&mut pe, products);
            assert_eq!(
                slice.peek_field_lane(reg, lsb, width, j),
                pe.regs().peek_field(reg, lsb, width),
                "lane {j}"
            );
            // And the popcount is what it should be.
            let pc = products.iter().filter(|&&b| b).count() as u32;
            assert_eq!(slice.peek_field_lane(reg, lsb, width, j), pc, "lane {j} popcount");
        }
    }

    #[test]
    fn clear_resets_all_lanes() {
        let mut sg = SequenceGenerator::new();
        let prog = sg.program(&OpDesc::ThresholdNode { n: 9, t_popcount: 2 });
        let mut slice = PeSlice::new();
        slice.run(&prog.schedule, |_| ONES);
        assert_ne!(slice.neuron_word(prog.out_neuron.unwrap()), 0);
        slice.clear();
        assert!(slice.neurons.iter().all(|&w| w == 0));
        assert!(slice.regs.iter().flatten().all(|&w| w == 0));
    }
}

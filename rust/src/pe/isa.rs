//! The TULIP-PE micro-op ISA — one control word per clock cycle.
//!
//! Fig. 3 of the paper: each of the four neurons `N1..N4` has inputs
//! `(a, b, c, d)` with weights `[2, 1, 1, 1]` and a run-time threshold `T`
//! driven by digital control signals. Inputs **b and c are shared buses**
//! across all four neurons ("so that the neuron can fetch data from its
//! local register, and broadcast it to all other neurons"); `a` and `d` are
//! private per-neuron muxes. Inter-neuron communication and register access
//! go through multiplexers; the reconfigurable sequence generator (§IV-E)
//! broadcasts one control word per cycle to every PE in the array.
//!
//! Modelling notes (documented deviations — see DESIGN.md §6):
//! * A "cascade of two binary neurons" implements a full adder (§III). We
//!   model the cascade with a two-phase cycle: phase-0 neurons latch first
//!   (carry), phase-1 neurons may sample a phase-0 neuron's *fresh* output
//!   within the same cycle ([`Src::NFresh`]). This is the two-level
//!   threshold network of Fig. 2(b)'s insets collapsed into one clock.
//! * Register-to-bus muxes are combinational, so a `w`-bit ripple addition
//!   takes exactly `w` cycles (sum bit `i` and, on the last cycle, the
//!   carry-out are written back in the same cycle they are produced).


/// Number of neurons in a TULIP-PE (§IV-A: four is the minimum that supports
/// addition, comparison, maxpooling and ReLU).
pub const NUM_NEURONS: usize = 4;
/// Local register width per neuron (§IV-A: 16-bit local registers).
pub const REG_BITS: usize = 16;
/// Number of local registers (one per neuron: R1..R4).
pub const NUM_REGS: usize = NUM_NEURONS;

/// A combinational bit source for buses and private inputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    /// Constant 0 (input mux disabled).
    Zero,
    /// Constant 1.
    One,
    /// External input channel `i` (XNOR-product feed / operand stream).
    Ext(usize),
    /// Latched output of neuron `k` as of the *previous* edge.
    N(usize),
    /// Complement of [`Src::N`].
    NInv(usize),
    /// Same-cycle (phase-0) output of neuron `k` — the neuron cascade.
    /// Only valid from a phase-1 neuron or a register write.
    NFresh(usize),
    /// Complement of [`Src::NFresh`].
    NFreshInv(usize),
    /// Bit `bit` of local register `reg`.
    Reg { reg: usize, bit: usize },
    /// Complement of [`Src::Reg`].
    RegInv { reg: usize, bit: usize },
}

impl Src {
    /// Does this source read a register? (→ energy accounting)
    pub fn reads_reg(&self) -> Option<usize> {
        match self {
            Src::Reg { reg, .. } | Src::RegInv { reg, .. } => Some(*reg),
            _ => None,
        }
    }

    /// Does this source depend on a same-cycle (fresh) neuron output?
    pub fn is_fresh(&self) -> bool {
        matches!(self, Src::NFresh(_) | Src::NFreshInv(_))
    }
}

/// Per-neuron control for one cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NeuronCtl {
    /// Clock-gated: the latch holds its value and no energy is consumed
    /// (§IV-E: "clock gating strategy whenever a part of the design is not
    /// used").
    pub gated: bool,
    /// Evaluation phase: 0 = first wave (e.g. carry), 1 = may read
    /// [`Src::NFresh`] outputs of phase-0 neurons (e.g. sum).
    pub phase: u8,
    /// Private input `a` (weight 2).
    pub a: Src,
    /// Take bus `b` (weight 1)? `false` contributes 0.
    pub b_en: bool,
    /// Complement the `b` bus tap for this neuron.
    pub b_inv: bool,
    /// Take bus `c` (weight 1)?
    pub c_en: bool,
    /// Complement the `c` bus tap.
    pub c_inv: bool,
    /// Private input `d` (weight 1).
    pub d: Src,
    /// Run-time threshold `T` for this cycle.
    pub threshold: i32,
}

impl NeuronCtl {
    /// A gated (idle) neuron.
    pub const fn idle() -> Self {
        NeuronCtl {
            gated: true,
            phase: 0,
            a: Src::Zero,
            b_en: false,
            b_inv: false,
            c_en: false,
            c_inv: false,
            d: Src::Zero,
            threshold: 1,
        }
    }

    /// An active neuron with all inputs defaulted off.
    pub const fn active(threshold: i32) -> Self {
        NeuronCtl { gated: false, threshold, ..Self::idle() }
    }
}

/// Source for an end-of-cycle register write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WSrc {
    /// Neuron `k`'s output *after* this cycle's evaluation.
    N(usize),
    /// Complement of [`WSrc::N`].
    NInv(usize),
    /// Neuron `k`'s output as of the previous edge (write-before-update;
    /// used to spill a carry latch while the neuron is being re-purposed).
    NOld(usize),
    /// External input channel `i`.
    Ext(usize),
    /// Register bit copy.
    Reg {
        /// Source register index.
        reg: usize,
        /// Source bit index.
        bit: usize,
    },
    /// Constant 0.
    Zero,
    /// Constant 1.
    One,
}

/// One end-of-cycle register-bit write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegWrite {
    /// Destination register index.
    pub reg: usize,
    /// Destination bit index.
    pub bit: usize,
    /// Value source.
    pub src: WSrc,
}

/// One cycle of PE control — what the sequence generator broadcasts.
#[derive(Debug, Clone, PartialEq)]
pub struct ControlWord {
    /// Shared bus `b` driver for this cycle.
    pub bus_b: Src,
    /// Shared bus `c` driver.
    pub bus_c: Src,
    /// Per-neuron control.
    pub neurons: [NeuronCtl; NUM_NEURONS],
    /// End-of-cycle register writes (latch-based file: ≤ 2 bit-writes per
    /// register per cycle — sum + carry-out on the final add cycle).
    pub writes: Vec<RegWrite>,
    /// Human-readable annotation for schedule visualization (Fig. 4/5).
    pub note: Option<String>,
}

impl ControlWord {
    /// An all-idle cycle.
    pub fn idle() -> Self {
        ControlWord {
            bus_b: Src::Zero,
            bus_c: Src::Zero,
            neurons: [NeuronCtl::idle(); NUM_NEURONS],
            writes: Vec::new(),
            note: None,
        }
    }

    /// Attach a note (builder style).
    pub fn with_note(mut self, s: impl Into<String>) -> Self {
        self.note = Some(s.into());
        self
    }

    /// Structural validation of the hardware constraints this word must
    /// respect. Returns a description of the first violation.
    pub fn validate(&self) -> std::result::Result<(), crate::Error> {
        let bad = |m: String| Err(crate::Error::InvalidSchedule(m));
        // Buses are resolved before phase 0 — they may not carry fresh taps.
        if self.bus_b.is_fresh() || self.bus_c.is_fresh() {
            return bad("bus driven by same-cycle neuron output".into());
        }
        for (k, n) in self.neurons.iter().enumerate() {
            if n.gated {
                continue;
            }
            if n.phase == 0 && (n.a.is_fresh() || n.d.is_fresh()) {
                return bad(format!("N{} is phase-0 but reads a fresh output", k + 1));
            }
            if let Src::NFresh(j) | Src::NFreshInv(j) = n.a {
                if self.neurons[j].phase != 0 || self.neurons[j].gated {
                    return bad(format!("N{} fresh-reads non-phase-0 N{}", k + 1, j + 1));
                }
            }
            if let Src::NFresh(j) | Src::NFreshInv(j) = n.d {
                if self.neurons[j].phase != 0 || self.neurons[j].gated {
                    return bad(format!("N{} fresh-reads non-phase-0 N{}", k + 1, j + 1));
                }
            }
            for s in [n.a, n.d] {
                if let Src::Reg { reg, bit } | Src::RegInv { reg, bit } = s {
                    if reg >= NUM_REGS || bit >= REG_BITS {
                        return bad(format!("N{} reads out-of-range R{}[{}]", k + 1, reg + 1, bit));
                    }
                }
            }
        }
        // ≤ 2 writes per register per cycle, no duplicate (reg,bit) targets.
        let mut seen = std::collections::HashSet::new();
        let mut per_reg = [0usize; NUM_REGS];
        for w in &self.writes {
            if w.reg >= NUM_REGS || w.bit >= REG_BITS {
                return bad(format!("write out of range R{}[{}]", w.reg + 1, w.bit));
            }
            if !seen.insert((w.reg, w.bit)) {
                return bad(format!("duplicate write to R{}[{}]", w.reg + 1, w.bit));
            }
            per_reg[w.reg] += 1;
            if per_reg[w.reg] > 2 {
                return bad(format!("more than 2 writes to R{} in one cycle", w.reg + 1));
            }
        }
        Ok(())
    }

    /// Number of neurons evaluating (not clock-gated) this cycle.
    pub fn active_neurons(&self) -> usize {
        self.neurons.iter().filter(|n| !n.gated).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_word_validates() {
        assert!(ControlWord::idle().validate().is_ok());
        assert_eq!(ControlWord::idle().active_neurons(), 0);
    }

    #[test]
    fn bus_cannot_be_fresh() {
        let mut cw = ControlWord::idle();
        cw.bus_b = Src::NFresh(2);
        assert!(cw.validate().is_err());
    }

    #[test]
    fn phase0_cannot_read_fresh() {
        let mut cw = ControlWord::idle();
        cw.neurons[1] =
            NeuronCtl { gated: false, phase: 0, a: Src::NFresh(2), ..NeuronCtl::idle() };
        assert!(cw.validate().is_err());
    }

    #[test]
    fn fresh_read_requires_phase0_producer() {
        let mut cw = ControlWord::idle();
        // N3 active phase 0, N2 phase-1 fresh-reads it: OK.
        cw.neurons[2] = NeuronCtl::active(2);
        cw.neurons[1] =
            NeuronCtl { gated: false, phase: 1, a: Src::NFreshInv(2), ..NeuronCtl::idle() };
        assert!(cw.validate().is_ok());
        // Producer gated → invalid.
        cw.neurons[2].gated = true;
        assert!(cw.validate().is_err());
    }

    #[test]
    fn duplicate_and_excess_writes_rejected() {
        let mut cw = ControlWord::idle();
        cw.writes = vec![
            RegWrite { reg: 1, bit: 0, src: WSrc::N(1) },
            RegWrite { reg: 1, bit: 0, src: WSrc::N(2) },
        ];
        assert!(cw.validate().is_err());
        cw.writes = vec![
            RegWrite { reg: 1, bit: 0, src: WSrc::N(1) },
            RegWrite { reg: 1, bit: 1, src: WSrc::N(2) },
            RegWrite { reg: 1, bit: 2, src: WSrc::N(3) },
        ];
        assert!(cw.validate().is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let mut cw = ControlWord::idle();
        cw.writes = vec![RegWrite { reg: 0, bit: REG_BITS, src: WSrc::Zero }];
        assert!(cw.validate().is_err());
        let mut cw = ControlWord::idle();
        cw.neurons[0] =
            NeuronCtl { gated: false, a: Src::Reg { reg: 9, bit: 0 }, ..NeuronCtl::idle() };
        assert!(cw.validate().is_err());
    }
}

//! Top-level architecture (Fig. 6): image buffer (two-stage L2/L1
//! standard-cell memory), kernel shift-register buffer, processing units
//! (XNOR array + 8 TULIP-PEs + simplified MAC each), output buffers and
//! the controller with its clock-gating strategy.
//!
//! * [`memory`] — buffer capacity + per-layer traffic model (feeds the
//!   energy model and the fetch-time side of the performance model).
//! * [`unit`] — the processing-unit structure used by the bit-true engine.
//! * [`controller`] — per-layer control programs and clock-gating
//!   bookkeeping.

pub mod controller;
pub mod memory;
pub mod unit;

//! The processing unit (Fig. 6): an XNOR product array feeding eight
//! TULIP-PEs (one OFM channel each) and one simplified MAC for integer
//! layers. 32 such units form the evaluated chip (256 PEs, 32 MACs).

use crate::baseline::MacUnit;
use crate::bnn::tensor::BinWeights;
use crate::pe::{PeStats, TulipPe};

/// XNOR product generation: "The inputs and weights are multiplied using
/// XNOR gates, to generate product terms."
pub fn xnor_products(window: &[bool], weights: &[i8]) -> Vec<bool> {
    assert_eq!(window.len(), weights.len());
    window.iter().zip(weights).map(|(&x, &w)| x == (w > 0)).collect()
}

/// Allocation-free variant for the bit-true hot loop (§Perf): writes the
/// products into a caller-owned buffer.
pub fn xnor_products_into(window: &[bool], weights: &[i8], out: &mut Vec<bool>) {
    assert_eq!(window.len(), weights.len());
    out.clear();
    out.extend(window.iter().zip(weights).map(|(&x, &w)| x == (w > 0)));
}

/// One processing unit.
#[derive(Debug, Clone)]
pub struct ProcessingUnit {
    /// The unit's TULIP-PEs (8 in the paper design), one OFM channel each.
    pub pes: Vec<TulipPe>,
    /// The unit's simplified MAC for integer layers (§V-C).
    pub mac: MacUnit,
}

impl ProcessingUnit {
    /// The paper's unit: 8 PEs + 1 simplified MAC.
    pub fn new(pes_per_unit: usize) -> Self {
        ProcessingUnit {
            pes: (0..pes_per_unit).map(|_| TulipPe::new()).collect(),
            mac: MacUnit::simplified(),
        }
    }

    /// Merged PE activity counters.
    pub fn pe_stats(&self) -> PeStats {
        let mut s = PeStats::default();
        for pe in &self.pes {
            s.merge(&pe.stats());
        }
        s
    }

    /// Reset every PE's activity counters.
    pub fn reset_stats(&mut self) {
        for pe in &mut self.pes {
            pe.reset_stats();
        }
    }
}

/// A SIMD array of processing units sharing one broadcast window
/// ("This window of input pixels is broadcasted to all the processing
/// units present in the design").
#[derive(Debug, Clone)]
pub struct PeArray {
    /// The processing units (32 in the paper design).
    pub units: Vec<ProcessingUnit>,
    /// PEs per unit (8 in the paper design).
    pub pes_per_unit: usize,
}

impl PeArray {
    /// An array of `num_units` units with `pes_per_unit` PEs each.
    pub fn new(num_units: usize, pes_per_unit: usize) -> Self {
        PeArray {
            units: (0..num_units).map(|_| ProcessingUnit::new(pes_per_unit)).collect(),
            pes_per_unit,
        }
    }

    /// Paper design point: 32 units × 8 PEs.
    pub fn paper() -> Self {
        Self::new(crate::energy::calib::NUM_MACS, crate::energy::calib::PES_PER_UNIT)
    }

    /// Total PE count across all units.
    pub fn num_pes(&self) -> usize {
        self.units.len() * self.pes_per_unit
    }

    /// Borrow PE `i` (array-flattened index).
    pub fn pe_mut(&mut self, i: usize) -> &mut TulipPe {
        let u = i / self.pes_per_unit;
        let p = i % self.pes_per_unit;
        &mut self.units[u].pes[p]
    }

    /// Generate per-PE product vectors for one broadcast window: PE `i`
    /// applies filter `channel_base + i`'s weights to the same window.
    pub fn products_for_window(
        &self,
        window: &[bool],
        weights: &BinWeights,
        channel_base: usize,
    ) -> Vec<Vec<bool>> {
        (0..self.num_pes())
            .filter(|i| channel_base + i < weights.z2)
            .map(|i| xnor_products(window, weights.filter(channel_base + i)))
            .collect()
    }

    /// Reset every PE's activity counters (per-image accounting in the
    /// batched engine; register contents and latches are left alone, as in
    /// the hardware, where only the energy counters are external).
    pub fn reset_stats(&mut self) {
        for u in &mut self.units {
            u.reset_stats();
        }
    }

    /// Total PE activity across the array.
    pub fn stats(&self) -> PeStats {
        let mut s = PeStats::default();
        for u in &self.units {
            s.merge(&u.pe_stats());
        }
        s
    }

    /// Per-PE activity counters in array-flattened index order (the same
    /// indexing as [`PeArray::pe_mut`]): the observability layer's source
    /// for per-PE utilization.
    pub fn per_pe_stats(&self) -> Vec<PeStats> {
        self.units.iter().flat_map(|u| u.pes.iter().map(|pe| pe.stats())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xnor_is_equality_of_sign() {
        assert_eq!(
            xnor_products(&[true, true, false, false], &[1, -1, 1, -1]),
            vec![true, false, false, true]
        );
    }

    #[test]
    fn array_geometry() {
        let arr = PeArray::paper();
        assert_eq!(arr.num_pes(), 256);
        assert_eq!(arr.units.len(), 32);
    }

    #[test]
    fn products_respect_channel_bounds() {
        let arr = PeArray::new(2, 2); // 4 PEs
        let w = BinWeights::random(3, 4, 1); // only 3 channels
        let window = vec![true, false, true, true];
        let prods = arr.products_for_window(&window, &w, 0);
        assert_eq!(prods.len(), 3); // clipped at z2
        assert_eq!(prods[0].len(), 4);
    }

    #[test]
    fn pe_indexing_is_stable() {
        let mut arr = PeArray::new(2, 3);
        arr.pe_mut(4).regs_mut().poke_field(0, 0, 4, 7);
        assert_eq!(arr.units[1].pes[1].regs().peek_field(0, 0, 4), 7);
    }
}

//! The processing unit (Fig. 6): an XNOR product array feeding eight
//! TULIP-PEs (one OFM channel each) and one simplified MAC for integer
//! layers. 32 such units form the evaluated chip (256 PEs, 32 MACs).

use crate::baseline::MacUnit;
use crate::bnn::tensor::BinWeights;
use crate::pe::slice::PeSlice;
use crate::pe::{PeStats, TulipPe};

/// XNOR product generation: "The inputs and weights are multiplied using
/// XNOR gates, to generate product terms."
pub fn xnor_products(window: &[bool], weights: &[i8]) -> Vec<bool> {
    assert_eq!(window.len(), weights.len());
    window.iter().zip(weights).map(|(&x, &w)| x == (w > 0)).collect()
}

/// Allocation-free variant for the bit-true hot loop (§Perf): writes the
/// products into a caller-owned buffer.
pub fn xnor_products_into(window: &[bool], weights: &[i8], out: &mut Vec<bool>) {
    assert_eq!(window.len(), weights.len());
    out.clear();
    out.extend(window.iter().zip(weights).map(|(&x, &w)| x == (w > 0)));
}

/// Word-level XNOR product generation for the bit-sliced engine: one
/// product bit across 64 lanes at once. XNOR against a +1 weight is the
/// identity; against a −1 weight it is complement — so the whole product
/// array degenerates to "pass or invert the lane word".
#[inline(always)]
pub fn xnor_product_word(window: u64, weight_plus: bool) -> u64 {
    if weight_plus {
        window
    } else {
        !window
    }
}

/// One processing unit.
#[derive(Debug, Clone)]
pub struct ProcessingUnit {
    /// The unit's TULIP-PEs (8 in the paper design), one OFM channel each.
    pub pes: Vec<TulipPe>,
    /// The unit's simplified MAC for integer layers (§V-C).
    pub mac: MacUnit,
}

impl ProcessingUnit {
    /// The paper's unit: 8 PEs + 1 simplified MAC.
    pub fn new(pes_per_unit: usize) -> Self {
        ProcessingUnit {
            pes: (0..pes_per_unit).map(|_| TulipPe::new()).collect(),
            mac: MacUnit::simplified(),
        }
    }

    /// Merged PE activity counters.
    pub fn pe_stats(&self) -> PeStats {
        let mut s = PeStats::default();
        for pe in &self.pes {
            s.merge(&pe.stats());
        }
        s
    }

    /// Reset every PE's activity counters.
    pub fn reset_stats(&mut self) {
        for pe in &mut self.pes {
            pe.reset_stats();
        }
    }
}

/// A SIMD array of processing units sharing one broadcast window
/// ("This window of input pixels is broadcasted to all the processing
/// units present in the design").
#[derive(Debug, Clone)]
pub struct PeArray {
    /// The processing units (32 in the paper design).
    pub units: Vec<ProcessingUnit>,
    /// PEs per unit (8 in the paper design).
    pub pes_per_unit: usize,
}

impl PeArray {
    /// An array of `num_units` units with `pes_per_unit` PEs each.
    pub fn new(num_units: usize, pes_per_unit: usize) -> Self {
        PeArray {
            units: (0..num_units).map(|_| ProcessingUnit::new(pes_per_unit)).collect(),
            pes_per_unit,
        }
    }

    /// Paper design point: 32 units × 8 PEs.
    pub fn paper() -> Self {
        Self::new(crate::energy::calib::NUM_MACS, crate::energy::calib::PES_PER_UNIT)
    }

    /// Total PE count across all units.
    pub fn num_pes(&self) -> usize {
        self.units.len() * self.pes_per_unit
    }

    /// Borrow PE `i` (array-flattened index).
    pub fn pe_mut(&mut self, i: usize) -> &mut TulipPe {
        let u = i / self.pes_per_unit;
        let p = i % self.pes_per_unit;
        &mut self.units[u].pes[p]
    }

    /// Generate per-PE product vectors for one broadcast window: PE `i`
    /// applies filter `channel_base + i`'s weights to the same window.
    pub fn products_for_window(
        &self,
        window: &[bool],
        weights: &BinWeights,
        channel_base: usize,
    ) -> Vec<Vec<bool>> {
        (0..self.num_pes())
            .filter(|i| channel_base + i < weights.z2)
            .map(|i| xnor_products(window, weights.filter(channel_base + i)))
            .collect()
    }

    /// Reset every PE's activity counters (per-image accounting in the
    /// batched engine; register contents and latches are left alone, as in
    /// the hardware, where only the energy counters are external).
    pub fn reset_stats(&mut self) {
        for u in &mut self.units {
            u.reset_stats();
        }
    }

    /// Total PE activity across the array.
    pub fn stats(&self) -> PeStats {
        let mut s = PeStats::default();
        for u in &self.units {
            s.merge(&u.pe_stats());
        }
        s
    }

    /// Per-PE activity counters in array-flattened index order (the same
    /// indexing as [`PeArray::pe_mut`]): the observability layer's source
    /// for per-PE utilization.
    pub fn per_pe_stats(&self) -> Vec<PeStats> {
        self.units.iter().flat_map(|u| u.pes.iter().map(|pe| pe.stats())).collect()
    }
}

/// The bit-sliced counterpart of [`PeArray`]: one reusable [`PeSlice`]
/// (64 lanes of lockstep PE state) plus analytically accumulated per-PE
/// activity counters, laid out in the same array-flattened index order as
/// [`PeArray::pe_mut`] so the observability layer cannot tell the engines
/// apart.
///
/// Where the scalar array owns 256 stateful `TulipPe`s that count as they
/// step, the sliced array owns *one* slice of lane state (cleared and
/// reused per program run) and books activity via [`SlicedArray::credit`]:
/// each modelled PE is credited with `unit_stats × runs` for every program
/// it would have executed — exact, because schedule activity is
/// control-flow determined (see
/// [`CachedProgram::unit_stats`](crate::scheduler::seqgen::CachedProgram::unit_stats)).
#[derive(Debug, Clone)]
pub struct SlicedArray {
    slice: PeSlice,
    per_pe: Vec<PeStats>,
    pes_per_unit: usize,
}

impl SlicedArray {
    /// An array modelling `num_units × pes_per_unit` PEs.
    pub fn new(num_units: usize, pes_per_unit: usize) -> Self {
        SlicedArray {
            slice: PeSlice::new(),
            per_pe: vec![PeStats::default(); num_units * pes_per_unit],
            pes_per_unit,
        }
    }

    /// Paper design point: 32 units × 8 PEs (matches [`PeArray::paper`]).
    pub fn paper() -> Self {
        Self::new(crate::energy::calib::NUM_MACS, crate::energy::calib::PES_PER_UNIT)
    }

    /// Total PE count modelled by this array.
    pub fn num_pes(&self) -> usize {
        self.per_pe.len()
    }

    /// PEs per unit (the channel→PE striping modulus).
    pub fn pes_per_unit(&self) -> usize {
        self.pes_per_unit
    }

    /// The shared lane state, cleared for a fresh program run.
    pub fn slice_mut(&mut self) -> &mut PeSlice {
        self.slice.clear();
        &mut self.slice
    }

    /// Credit modelled PE `pe` with `runs` executions of a program whose
    /// single-run activity is `unit`.
    pub fn credit(&mut self, pe: usize, unit: &PeStats, runs: u64) {
        self.per_pe[pe].merge(&unit.scaled(runs));
    }

    /// Total credited PE activity across the array.
    pub fn stats(&self) -> PeStats {
        let mut s = PeStats::default();
        for pe in &self.per_pe {
            s.merge(pe);
        }
        s
    }

    /// Per-PE activity counters in array-flattened index order.
    pub fn per_pe_stats(&self) -> Vec<PeStats> {
        self.per_pe.clone()
    }

    /// Zero the credited activity counters.
    pub fn reset_stats(&mut self) {
        self.per_pe.fill(PeStats::default());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xnor_is_equality_of_sign() {
        assert_eq!(
            xnor_products(&[true, true, false, false], &[1, -1, 1, -1]),
            vec![true, false, false, true]
        );
    }

    #[test]
    fn array_geometry() {
        let arr = PeArray::paper();
        assert_eq!(arr.num_pes(), 256);
        assert_eq!(arr.units.len(), 32);
    }

    #[test]
    fn products_respect_channel_bounds() {
        let arr = PeArray::new(2, 2); // 4 PEs
        let w = BinWeights::random(3, 4, 1); // only 3 channels
        let window = vec![true, false, true, true];
        let prods = arr.products_for_window(&window, &w, 0);
        assert_eq!(prods.len(), 3); // clipped at z2
        assert_eq!(prods[0].len(), 4);
    }

    #[test]
    fn xnor_word_passes_or_inverts() {
        let w = 0xdead_beef_0123_4567u64;
        assert_eq!(xnor_product_word(w, true), w);
        assert_eq!(xnor_product_word(w, false), !w);
    }

    #[test]
    fn sliced_array_credits_and_partitions() {
        let mut arr = SlicedArray::new(2, 4);
        assert_eq!(arr.num_pes(), 8);
        let unit = PeStats {
            cycles: 3,
            neuron_evals: 5,
            gated_neuron_cycles: 7,
            reg_reads: 2,
            reg_writes: 1,
        };
        arr.credit(1, &unit, 10);
        arr.credit(5, &unit, 1);
        let per = arr.per_pe_stats();
        assert_eq!(per[1].neuron_evals, 50);
        assert_eq!(per[5].cycles, 3);
        assert_eq!(per[0], PeStats::default());
        // The totals are the per-PE sum (the partition invariant).
        let mut sum = PeStats::default();
        for p in &per {
            sum.merge(p);
        }
        assert_eq!(arr.stats(), sum);
        arr.reset_stats();
        assert_eq!(arr.stats(), PeStats::default());
    }

    #[test]
    fn pe_indexing_is_stable() {
        let mut arr = PeArray::new(2, 3);
        arr.pe_mut(4).regs_mut().poke_field(0, 0, 4, 7);
        assert_eq!(arr.units[1].pes[1].regs().peek_field(0, 0, 4), 7);
    }
}

//! The memory subsystem (Fig. 6) and its per-layer traffic model.
//!
//! "The image buffer is a two-stage standard cell memory (SCM) named L2 and
//! L1. … 32 input feature maps are loaded on-chip into L2 on a
//! pixel-by-pixel basis. Once L2 is loaded with IFMs, L1 starts fetching
//! the window of IFM pixels needed for the convolution operation, on a
//! window-by-window basis. This window of input pixels is broadcasted to
//! all the processing units." The kernel buffer is a shift register loaded
//! with the layer's binary weights before inputs arrive.
//!
//! Traffic accounting per layer (all quantities in bits):
//! * off-chip → L2: every IFM slab is fetched `Z` times (Table III);
//! * L2 → L1: each resident pixel crosses once per slab per batch (the L1
//!   holds the k-row working set, so window overlap is not re-fetched);
//! * L1 → units: one `k²·slab` window broadcast per output pixel — the
//!   broadcast is shared by **all** units, which is what makes OFM-parallel
//!   batching cheap;
//! * kernel buffer: weights enter once per layer and shift locally;
//! * output buffer: final OFM bits, plus 16-bit partial sums when `P > 1`.

use crate::bnn::Layer;
use crate::config::ArchConfig;
use crate::coordinator::tiling::Tiling;
use crate::energy::Activity;

/// Capacity model of the two-stage image buffer.
#[derive(Debug, Clone, Copy)]
pub struct ImageBuffer {
    /// L2 capacity in bits (32 IFMs × up-to-32×32 px × 12 bit in the
    /// evaluated configuration).
    pub l2_bits: u64,
    /// L1 working-set capacity in bits (k rows of the slab).
    pub l1_bits: u64,
}

impl ImageBuffer {
    /// The evaluated design point: fits 32 12-bit 32×32 IFMs in L2.
    pub fn paper() -> Self {
        ImageBuffer { l2_bits: 32 * 32 * 32 * 12, l1_bits: 32 * 3 * 32 * 12 }
    }

    /// Can a slab of `ifms` maps of `x1 × y1` pixels at `bits`/pixel reside
    /// in L2? (When it cannot, the layer runs in image parts — Table III's
    /// "Parts" column.)
    pub fn slab_fits(&self, ifms: usize, x1: usize, y1: usize, bits: u32) -> bool {
        (ifms * x1 * y1) as u64 * bits as u64 <= self.l2_bits
    }

    /// Number of image parts needed for a layer's slab.
    pub fn parts_needed(&self, ifms: usize, x1: usize, y1: usize, bits: u32) -> usize {
        let need = (ifms * x1 * y1) as u64 * bits as u64;
        need.div_ceil(self.l2_bits) as usize
    }
}

/// Traffic + fetch-time for one conv/FC layer under a tiling.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerTraffic {
    /// Memory fields of the activity record (PE/MAC fields zero).
    pub activity: Activity,
    /// Cycles the off-chip interface needs (input + weight streams).
    pub fetch_cycles: u64,
}

/// Compute the traffic for a convolution layer.
pub fn conv_traffic(layer: &Layer, t: &Tiling, cfg: &ArchConfig) -> LayerTraffic {
    let (x2, y2) = layer.output_spatial();
    let px_in = (layer.x1 * layer.y1) as u64;
    let px_out = (x2 * y2) as u64;
    // Off-chip/L2 movement is in buffer-slot widths: the image buffers are
    // built for up-to-12-bit pixels (§V-A) and the Z-driven refetch economy
    // of Table III presumes binary pixels still occupy a slot on the
    // external interface (calib: BIN_PIXEL_BITS). On-chip L1 window
    // broadcasts move only the bits the XNOR array consumes.
    let slot_bits = if layer.is_binary() {
        crate::energy::calib::BIN_PIXEL_BITS
    } else {
        crate::energy::calib::INT_PIXEL_BITS
    };
    let in_bits = layer.input_bits as u64;
    let z1 = layer.z1 as u64;
    let z2 = layer.z2 as u64;
    let zb = t.z as u64;
    let fanin = layer.fanin() as u64;

    // Off-chip input stream: the full IFM set, Z times over (slot width).
    let offchip_input = z1 * px_in * slot_bits * zb;
    // Weights load once per layer into the kernel shift buffer.
    let weight_bits = layer.weight_bits();
    // L2 → L1: every resident pixel crosses once per (slab, batch).
    let l2_to_l1 = z1 * px_in * slot_bits * zb;
    // L1 window broadcasts: one fanin-wide window per output pixel per
    // batch (broadcast shared across units).
    let l1_reads = fanin * in_bits * px_out * zb;
    // Output: OFM bits (1-bit binary / 12-bit integer), plus 16-bit partial
    // sums stored and re-read for every extra slab pass.
    let out_bits_per = if layer.is_binary() { 1 } else { 12 };
    let outbuf =
        px_out * z2 * out_bits_per + (t.p.saturating_sub(1) as u64) * px_out * z2 * 16 * 2;
    // XNOR product generation: every MAC-op's multiply.
    let xnor = fanin * px_out * z2;

    let activity = Activity {
        offchip_bits: offchip_input,
        offchip_weight_bits: weight_bits,
        l2_write_bits: offchip_input,
        l2_to_l1_bits: l2_to_l1,
        l1_read_bits: l1_reads,
        kernel_shift_bits: weight_bits,
        outbuf_bits: outbuf,
        xnor_bits: xnor,
        ..Default::default()
    };
    let fetch_cycles =
        ((offchip_input + weight_bits) as f64 / cfg.offchip_bits_per_cycle).ceil() as u64;
    LayerTraffic { activity, fetch_cycles }
}

/// Traffic for a fully connected layer: the weight matrix dominates and is
/// streamed from off-chip ("memory consumes significantly more energy than
/// the processing units when executing fully connected layers", §V-C).
pub fn fc_traffic(layer: &Layer, _t: &Tiling, cfg: &ArchConfig) -> LayerTraffic {
    let in_bits = layer.input_bits as u64;
    let weight_bits = layer.weight_bits();
    let act_in = layer.z1 as u64 * in_bits;
    let act_out = layer.z2 as u64;
    let activity = Activity {
        offchip_bits: act_in,
        offchip_weight_bits: weight_bits,
        l2_write_bits: act_in,
        l1_read_bits: act_in * layer.z2.div_ceil(256).max(1) as u64,
        kernel_shift_bits: weight_bits,
        outbuf_bits: act_out,
        xnor_bits: layer.z1 as u64 * layer.z2 as u64,
        ..Default::default()
    };
    let fetch_cycles = (weight_bits as f64 / cfg.weight_bits_per_cycle).ceil() as u64;
    LayerTraffic { activity, fetch_cycles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::{alexnet, binarynet_cifar10};
    use crate::coordinator::tiling::tiling;

    #[test]
    fn l2_fits_paper_slab() {
        let buf = ImageBuffer::paper();
        // 32 CIFAR-sized IFMs at 12 bits fit exactly.
        assert!(buf.slab_fits(32, 32, 32, 12));
        // AlexNet conv1 input (227×227) needs multiple parts — Table III
        // lists 4.
        assert!(!buf.slab_fits(3, 227, 227, 12));
        let parts = buf.parts_needed(3, 227, 227, 12);
        assert!((2..=6).contains(&parts), "{parts}");
    }

    /// TULIP fetches binary-layer inputs ~6× less than YodaNN on AlexNet
    /// conv3 (Z = 2 vs 12) — the Table III claim in traffic form.
    #[test]
    fn tulip_fetches_less_on_binary_layers() {
        let net = alexnet();
        let conv3 = &net.layers[2];
        let tul = ArchConfig::tulip();
        let yod = ArchConfig::yodann();
        let t_t = conv_traffic(conv3, &tiling(conv3, &tul), &tul);
        let t_y = conv_traffic(conv3, &tiling(conv3, &yod), &yod);
        let ratio = t_y.activity.offchip_bits as f64 / t_t.activity.offchip_bits as f64;
        assert!(ratio > 3.0, "offchip ratio {ratio}");
    }

    /// Integer layers: identical traffic on both designs.
    #[test]
    fn integer_layer_traffic_identical() {
        let net = alexnet();
        let conv2 = &net.layers[1];
        let tul = ArchConfig::tulip();
        let yod = ArchConfig::yodann();
        let a = conv_traffic(conv2, &tiling(conv2, &tul), &tul).activity;
        let b = conv_traffic(conv2, &tiling(conv2, &yod), &yod).activity;
        assert_eq!(a.offchip_bits, b.offchip_bits);
        assert_eq!(a.l1_read_bits, b.l1_read_bits);
    }

    /// FC traffic is weight-dominated.
    #[test]
    fn fc_weight_dominated() {
        let net = binarynet_cifar10();
        let fc1 = &net.layers[6];
        let cfg = ArchConfig::tulip();
        let t = fc_traffic(fc1, &tiling(fc1, &cfg), &cfg);
        assert!(t.activity.offchip_weight_bits as f64 / t.activity.outbuf_bits as f64 > 100.0);
        assert_eq!(t.fetch_cycles, (fc1.weight_bits() as f64 / 1.0).ceil() as u64);
    }

    #[test]
    fn xnor_bits_equal_mac_ops_half() {
        let net = binarynet_cifar10();
        let conv2 = &net.layers[1];
        let cfg = ArchConfig::tulip();
        let t = conv_traffic(conv2, &tiling(conv2, &cfg), &cfg);
        // ops() counts 2 ops per product + compares.
        let (x2, y2) = conv2.output_spatial();
        let products = conv2.fanin() as u64 * (x2 * y2) as u64 * conv2.z2 as u64;
        assert_eq!(t.activity.xnor_bits, products);
    }
}

//! `serve` — the production inference front-end.
//!
//! Everything below `serve` exists to keep the bit-sliced engine's 64-lane
//! control words full while staying honest about what happens to every
//! request. The subsystem is a small pipeline:
//!
//! 1. [`protocol`] — the `tulip.serve/v1` JSON-lines wire format (std-only
//!    parser, packed-bits codec, typed requests/responses);
//! 2. [`queue`] — a bounded admission queue with configurable backpressure
//!    ([`BackpressurePolicy::Block`] vs [`BackpressurePolicy::Reject`]);
//! 3. [`shed`] — deadline enforcement at dequeue: expired requests are
//!    answered `shed` and counted, never executed and never dropped;
//! 4. [`batcher`] — dynamic micro-batching (flush on `max_batch` or
//!    `max_wait_us`) over the shared
//!    [`BatchExecutor`](crate::coordinator::BatchExecutor);
//! 5. [`server`] — the TCP accept loop, per-connection reader/writer
//!    threads, and graceful drain with a final
//!    [`PerfReport`](crate::coordinator::PerfReport).
//!
//! The accounting invariant the whole design is built around:
//! **`admitted == completed + shed + failed`** at drain time — every
//! admitted request is answered exactly once, and the final report proves
//! it ([`ServeStats::accounted`]).

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod server;
pub mod shed;

pub use batcher::{Batcher, ServeAggregate};
pub use protocol::{pack_bits, unpack_bits, ServeResponse, Status};
pub use queue::{BackpressurePolicy, BoundedQueue, ServeRequest};
pub use server::{request_drain, serve, ServeHandle};
pub use shed::Shedder;

use crate::bnn::tensor::BinWeights;
use crate::bnn::{tiny_bnn, Network};
use crate::metrics::{HistogramSnapshot, MetricsRegistry};

/// Server configuration (CLI flags of `tulip serve` map 1:1 onto these).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests do this).
    pub addr: String,
    /// Micro-batch flush size. The default, 64, is one bit-sliced lane
    /// word — the point where the SWAR engine's occupancy saturates.
    pub max_batch: usize,
    /// Maximum time a dequeued micro-batch waits to fill, microseconds.
    pub max_wait_us: u64,
    /// Admission queue capacity.
    pub queue_cap: usize,
    /// What to do with new requests when the queue is full.
    pub policy: BackpressurePolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 64,
            max_wait_us: 2_000,
            queue_cap: 1_024,
            policy: BackpressurePolicy::default(),
        }
    }
}

/// Frozen serving-layer accounting: the counters and latency/occupancy
/// histograms a draining server embeds in its final
/// [`PerfReport`](crate::coordinator::PerfReport).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// Requests admitted to the queue.
    pub admitted: u64,
    /// Requests refused at admission (queue full under `Reject`, or
    /// draining).
    pub rejected: u64,
    /// Admitted requests shed at dequeue because their deadline expired.
    pub shed: u64,
    /// Admitted requests classified and answered `ok`.
    pub completed: u64,
    /// Admitted requests answered `error` because the engine failed.
    pub failed: u64,
    /// `serve.batch_occupancy` — images per executed micro-batch.
    pub occupancy: HistogramSnapshot,
    /// `serve.latency_us.queue` — admission-to-dequeue time.
    pub queue_us: HistogramSnapshot,
    /// `serve.latency_us.batch` — engine wall time per micro-batch.
    pub batch_us: HistogramSnapshot,
    /// `serve.latency_us.total` — admission-to-response time.
    pub total_us: HistogramSnapshot,
}

impl ServeStats {
    /// Snapshot the serve instruments out of a registry.
    pub fn from_registry(reg: &MetricsRegistry) -> Self {
        ServeStats {
            admitted: reg.counter("serve.admitted").get(),
            rejected: reg.counter("serve.rejected").get(),
            shed: reg.counter("serve.shed").get(),
            completed: reg.counter("serve.completed").get(),
            failed: reg.counter("serve.failed").get(),
            occupancy: reg.histogram("serve.batch_occupancy").snapshot(),
            queue_us: reg.histogram("serve.latency_us.queue").snapshot(),
            batch_us: reg.histogram("serve.latency_us.batch").snapshot(),
            total_us: reg.histogram("serve.latency_us.total").snapshot(),
        }
    }

    /// The drain invariant: every admitted request was answered exactly
    /// once — `admitted == completed + shed + failed`. (Rejected requests
    /// were never admitted, so they are not part of the sum.)
    pub fn accounted(&self) -> bool {
        self.admitted == self.completed + self.shed + self.failed
    }

    /// One-line JSON (the reply to the `{"op": "stats"}` control message).
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"op\": \"stats\", \"admitted\": {}, \"rejected\": {}, \"shed\": {}, \
             \"completed\": {}, \"failed\": {}, \"occupancy_mean\": {:.3}, \
             \"queue_p99_us\": {}, \"total_p99_us\": {}}}",
            self.admitted,
            self.rejected,
            self.shed,
            self.completed,
            self.failed,
            self.occupancy.mean(),
            self.queue_us.quantile(0.99),
            self.total_us.quantile(0.99)
        )
    }
}

/// The demo networks `tulip serve`, `load_client` and the integration
/// tests agree on, keyed by name (weights are seeded deterministically, so
/// client and server can be built independently and still match bit for
/// bit): `"tiny"` → `tiny_bnn(16, 8, 4)` (16×16×8 input), `"tiny8"` →
/// `tiny_bnn(8, 4, 3)` (8×8×4 input).
pub fn demo_network(name: &str) -> Option<(Network, Vec<BinWeights>)> {
    let net = match name {
        "tiny" => tiny_bnn(16, 8, 4),
        "tiny8" => tiny_bnn(8, 4, 3),
        _ => return None,
    };
    let weights: Vec<BinWeights> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 1000 + i as u64))
        .collect();
    Some((net, weights))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_networks_resolve() {
        let (net, w) = demo_network("tiny8").unwrap();
        assert_eq!(net.layers.len(), w.len());
        assert_eq!((net.layers[0].y1, net.layers[0].x1, net.layers[0].z1), (8, 8, 4));
        assert!(demo_network("tiny").is_some());
        assert!(demo_network("nope").is_none());
    }

    #[test]
    fn stats_accounting_invariant() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.admitted").add(10);
        reg.counter("serve.completed").add(7);
        reg.counter("serve.shed").add(2);
        reg.counter("serve.failed").add(1);
        reg.counter("serve.rejected").add(5);
        let s = ServeStats::from_registry(&reg);
        assert!(s.accounted());
        assert!(s.to_json_line().contains("\"admitted\": 10"));
        let reg = MetricsRegistry::new();
        reg.counter("serve.admitted").add(3);
        assert!(!ServeStats::from_registry(&reg).accounted());
    }
}

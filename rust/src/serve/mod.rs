//! `serve` — the production inference front-end.
//!
//! Everything below `serve` exists to keep the bit-sliced engine's 64-lane
//! control words full while staying honest about what happens to every
//! request. The subsystem is a small pipeline:
//!
//! 1. [`protocol`] — the `tulip.serve/v1` JSON-lines wire format (std-only
//!    parser, packed-bits codec, typed requests/responses);
//! 2. [`queue`] — a bounded admission queue with configurable backpressure
//!    ([`BackpressurePolicy::Block`] vs [`BackpressurePolicy::Reject`]);
//! 3. [`shed`] — deadline enforcement at dequeue: expired requests are
//!    answered `shed` and counted, never executed and never dropped;
//! 4. [`batcher`] — dynamic micro-batching (flush on `max_batch` or
//!    `max_wait_us`) over a shared
//!    [`BatchExecutor`](crate::coordinator::BatchExecutor);
//! 5. [`registry`] — the multi-model registry: every loaded
//!    [`Model`](crate::bnn::Model) gets its own queue + batcher lane and
//!    its own accounting, and models can be hot-loaded and drained out of
//!    a live server;
//! 6. [`server`] — the TCP accept loop, per-connection reader/writer
//!    threads, request routing by model name, and graceful drain with a
//!    final per-model [`ServeReport`];
//! 7. [`telemetry`] — the live observability plane: an HTTP endpoint
//!    (`--metrics-addr`) serving Prometheus text exposition for the
//!    global registry plus every lane (`/metrics`), liveness/readiness
//!    probes (`/healthz`, `/readyz`) and flight-recorder dumps
//!    (`/trace`).
//!
//! The accounting invariant the whole design is built around:
//! **`admitted == completed + shed + failed`** at drain time — every
//! admitted request is answered exactly once, *per model and in total*,
//! and the final report proves it ([`ServeStats::accounted`]).

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod server;
pub mod shed;
pub mod telemetry;

pub use batcher::{Batcher, ServeAggregate};
pub use protocol::{pack_bits, unpack_bits, ServeResponse, Status};
pub use queue::{BackpressurePolicy, BoundedQueue, ServeRequest};
pub use registry::{ModelDrain, ModelRegistry};
pub use server::{request_drain, serve, ServeHandle, ServeReport};
pub use shed::Shedder;
pub use telemetry::TelemetryHandle;

use crate::bnn::tensor::BinWeights;
use crate::bnn::{Model, Network};
use crate::coordinator::ForwardEngine;
use crate::metrics::{HistogramSnapshot, MetricsRegistry};

/// Server configuration (CLI flags of `tulip serve` map 1:1 onto these).
///
/// The struct is `#[non_exhaustive]`: build it with [`ServeConfig::builder`]
/// (or start from [`ServeConfig::default`] and mutate fields) so new knobs
/// can be added without breaking callers.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests do this).
    pub addr: String,
    /// Micro-batch flush size. The default, 64, is one bit-sliced lane
    /// word — the point where the SWAR engine's occupancy saturates.
    pub max_batch: usize,
    /// Maximum time a dequeued micro-batch waits to fill, microseconds.
    pub max_wait_us: u64,
    /// Admission queue capacity (per model lane).
    pub queue_cap: usize,
    /// What to do with new requests when the queue is full.
    pub policy: BackpressurePolicy,
    /// Simulated PE array geometry as `(units, pes_per_unit)`; `None`
    /// keeps each executor's calibrated default.
    pub array: Option<(usize, usize)>,
    /// Rayon worker threads per model executor (0 = rayon's default).
    pub threads: usize,
    /// Forward engine every model lane executes with.
    pub engine: ForwardEngine,
    /// Bind address for the live-telemetry HTTP endpoint (`/metrics`,
    /// `/healthz`, `/readyz`, `/trace`); `None` disables it.
    pub metrics_addr: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            max_batch: 64,
            max_wait_us: 2_000,
            queue_cap: 1_024,
            policy: BackpressurePolicy::default(),
            array: None,
            threads: 0,
            engine: ForwardEngine::default(),
            metrics_addr: None,
        }
    }
}

impl ServeConfig {
    /// Start a builder from the defaults.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }
}

/// Builder for [`ServeConfig`] (the struct is `#[non_exhaustive]`).
///
/// ```
/// use tulip::serve::{BackpressurePolicy, ServeConfig};
///
/// let cfg = ServeConfig::builder()
///     .addr("127.0.0.1:0")
///     .max_batch(16)
///     .max_wait_us(500)
///     .policy(BackpressurePolicy::Reject)
///     .build();
/// assert_eq!(cfg.max_batch, 16);
/// assert_eq!(cfg.queue_cap, 1024); // untouched knobs keep their defaults
/// ```
#[derive(Debug, Clone, Default)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    /// Bind address (port 0 picks a free port).
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.addr = addr.into();
        self
    }

    /// Micro-batch flush size.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Micro-batch fill wait, microseconds.
    pub fn max_wait_us(mut self, us: u64) -> Self {
        self.cfg.max_wait_us = us;
        self
    }

    /// Per-model admission queue capacity.
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.cfg.queue_cap = cap;
        self
    }

    /// Backpressure policy when a queue is full.
    pub fn policy(mut self, policy: BackpressurePolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    /// Simulated PE array geometry `(units, pes_per_unit)`.
    pub fn array(mut self, units: usize, pes_per_unit: usize) -> Self {
        self.cfg.array = Some((units, pes_per_unit));
        self
    }

    /// Rayon worker threads per executor (0 = rayon's default).
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Forward engine for every model lane.
    pub fn engine(mut self, engine: ForwardEngine) -> Self {
        self.cfg.engine = engine;
        self
    }

    /// Bind address for the live-telemetry HTTP endpoint (port 0 picks a
    /// free port; see [`server::ServeHandle::metrics_addr`]).
    pub fn metrics_addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.metrics_addr = Some(addr.into());
        self
    }

    /// Finalize the configuration.
    pub fn build(self) -> ServeConfig {
        self.cfg
    }
}

/// Frozen serving-layer accounting: the counters and latency/occupancy
/// histograms a draining server embeds in its final
/// [`PerfReport`](crate::coordinator::PerfReport) — one per model lane,
/// rolled up into a server-wide total via [`ServeStats::merge`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// Requests admitted to the queue.
    pub admitted: u64,
    /// Requests refused at admission (queue full under `Reject`, or
    /// draining).
    pub rejected: u64,
    /// Admitted requests shed at dequeue because their deadline expired.
    pub shed: u64,
    /// Admitted requests classified and answered `ok`.
    pub completed: u64,
    /// Admitted requests answered `error` because the engine failed.
    pub failed: u64,
    /// `serve.batch_occupancy` — images per executed micro-batch.
    pub occupancy: HistogramSnapshot,
    /// `serve.latency_us.queue` — admission-to-dequeue time.
    pub queue_us: HistogramSnapshot,
    /// `serve.latency_us.batch` — engine wall time per micro-batch.
    pub batch_us: HistogramSnapshot,
    /// `serve.latency_us.total` — admission-to-response time.
    pub total_us: HistogramSnapshot,
}

impl ServeStats {
    /// Snapshot the serve instruments out of a registry.
    pub fn from_registry(reg: &MetricsRegistry) -> Self {
        ServeStats {
            admitted: reg.counter("serve.admitted").get(),
            rejected: reg.counter("serve.rejected").get(),
            shed: reg.counter("serve.shed").get(),
            completed: reg.counter("serve.completed").get(),
            failed: reg.counter("serve.failed").get(),
            occupancy: reg.histogram("serve.batch_occupancy").snapshot(),
            queue_us: reg.histogram("serve.latency_us.queue").snapshot(),
            batch_us: reg.histogram("serve.latency_us.batch").snapshot(),
            total_us: reg.histogram("serve.latency_us.total").snapshot(),
        }
    }

    /// The drain invariant: every admitted request was answered exactly
    /// once — `admitted == completed + shed + failed`. (Rejected requests
    /// were never admitted, so they are not part of the sum.)
    pub fn accounted(&self) -> bool {
        self.admitted == self.completed + self.shed + self.failed
    }

    /// Fold another lane's accounting into this one (counters add,
    /// histograms merge bucket-wise). The invariant is compositional:
    /// if both sides are [`accounted`](Self::accounted), so is the sum.
    pub fn merge(&mut self, other: &ServeStats) {
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.shed += other.shed;
        self.completed += other.completed;
        self.failed += other.failed;
        self.occupancy.merge(&other.occupancy);
        self.queue_us.merge(&other.queue_us);
        self.batch_us.merge(&other.batch_us);
        self.total_us.merge(&other.total_us);
    }

    /// One-line JSON (the reply to the `{"op": "stats"}` control message).
    pub fn to_json_line(&self) -> String {
        format!("{{\"op\": \"stats\", {}}}", self.json_fields())
    }

    /// The counter/quantile fields without the surrounding braces, so
    /// callers can embed them next to their own fields (per-model stats,
    /// unload receipts).
    pub fn json_fields(&self) -> String {
        format!(
            "\"admitted\": {}, \"rejected\": {}, \"shed\": {}, \
             \"completed\": {}, \"failed\": {}, \"occupancy_mean\": {:.3}, \
             \"queue_p99_us\": {}, \"total_p99_us\": {}",
            self.admitted,
            self.rejected,
            self.shed,
            self.completed,
            self.failed,
            self.occupancy.mean(),
            self.queue_us.quantile(0.99),
            self.total_us.quantile(0.99)
        )
    }
}

/// The demo networks `tulip serve`, `load_client` and the integration
/// tests agree on.
#[deprecated(since = "0.2.0", note = "use bnn::Model::demo; removed next release")]
#[doc(hidden)]
pub fn demo_network(name: &str) -> Option<(Network, Vec<BinWeights>)> {
    Model::demo(name).map(|m| (m.network().clone(), m.weights().to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_models_resolve() {
        let m = Model::demo("tiny8").unwrap();
        assert_eq!(m.network().layers.len(), m.weights().len());
        assert_eq!(m.input_dims(), (8, 8, 4));
        assert!(Model::demo("tiny").is_some());
        assert!(Model::demo("nope").is_none());
    }

    #[test]
    fn builder_covers_every_knob() {
        let cfg = ServeConfig::builder()
            .addr("0.0.0.0:7171")
            .max_batch(8)
            .max_wait_us(100)
            .queue_cap(32)
            .policy(BackpressurePolicy::Reject)
            .array(2, 8)
            .threads(3)
            .engine(ForwardEngine::Scalar)
            .metrics_addr("127.0.0.1:9091")
            .build();
        assert_eq!(cfg.addr, "0.0.0.0:7171");
        assert_eq!((cfg.max_batch, cfg.max_wait_us, cfg.queue_cap), (8, 100, 32));
        assert_eq!(cfg.policy, BackpressurePolicy::Reject);
        assert_eq!(cfg.array, Some((2, 8)));
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.engine, ForwardEngine::Scalar);
        assert_eq!(cfg.metrics_addr.as_deref(), Some("127.0.0.1:9091"));
        assert_eq!(ServeConfig::default().metrics_addr, None, "telemetry is opt-in");
    }

    #[test]
    fn stats_accounting_invariant() {
        let reg = MetricsRegistry::new();
        reg.counter("serve.admitted").add(10);
        reg.counter("serve.completed").add(7);
        reg.counter("serve.shed").add(2);
        reg.counter("serve.failed").add(1);
        reg.counter("serve.rejected").add(5);
        let s = ServeStats::from_registry(&reg);
        assert!(s.accounted());
        assert!(s.to_json_line().contains("\"admitted\": 10"));
        let reg = MetricsRegistry::new();
        reg.counter("serve.admitted").add(3);
        assert!(!ServeStats::from_registry(&reg).accounted());
    }

    #[test]
    fn stats_merge_is_compositional() {
        let reg_a = MetricsRegistry::new();
        reg_a.counter("serve.admitted").add(4);
        reg_a.counter("serve.completed").add(3);
        reg_a.counter("serve.shed").add(1);
        reg_a.histogram("serve.latency_us.total").observe(100);
        let reg_b = MetricsRegistry::new();
        reg_b.counter("serve.admitted").add(2);
        reg_b.counter("serve.completed").add(2);
        reg_b.counter("serve.rejected").add(9);
        reg_b.histogram("serve.latency_us.total").observe(3000);
        let mut total = ServeStats::from_registry(&reg_a);
        total.merge(&ServeStats::from_registry(&reg_b));
        assert_eq!((total.admitted, total.completed, total.shed, total.rejected), (6, 5, 1, 9));
        assert!(total.accounted());
        assert_eq!(total.total_us.count, 2);
        assert_eq!((total.total_us.min, total.total_us.max), (100, 3000));
    }
}

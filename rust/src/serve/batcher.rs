//! Dynamic micro-batching: the loop that turns single-image requests into
//! full lane words.
//!
//! The bit-sliced engine advances 64 lanes per broadcast control word, so
//! the efficiency of the whole server reduces to one question: *how full
//! are the words it executes?* The [`Batcher`] dequeues micro-batches from
//! the admission queue (flushing on `max_batch` or `max_wait`, whichever
//! first), sheds expired requests, runs the survivors through the shared
//! [`BatchExecutor`], and replies per request. Batch occupancy is recorded
//! in the `serve.batch_occupancy` histogram — the key efficiency metric —
//! and per-request latency splits into `serve.latency_us.{queue,batch,total}`.

use super::queue::{BoundedQueue, ServeRequest};
use super::shed::Shedder;
use crate::coordinator::{BatchExecutor, BatchRequest, BatchResult, WorkerSummary};
use crate::metrics::flight::{self, FlightStage};
use crate::metrics::{Counter, Histogram, MetricsRegistry, WindowHistogram};
use crate::pe::PeStats;
use crate::serve::protocol::ServeResponse;
use crate::sim::cycle::LayerObs;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Engine-side aggregates accumulated across every micro-batch a server
/// executed — the raw material for the final drain-time `PerfReport`.
#[derive(Debug, Clone, Default)]
pub struct ServeAggregate {
    /// Micro-batches executed.
    pub batches: u64,
    /// Images classified (== `serve.completed`).
    pub images: u64,
    /// Simulated chip cycles summed over all batches.
    pub cycles: u64,
    /// PE activity summed over all batches.
    pub stats: PeStats,
    /// Per-layer breakdown merged across all batches.
    pub layers: Vec<LayerObs>,
    /// Per-PE activity merged across all batches.
    pub per_pe: Vec<PeStats>,
    /// Summed engine wall time (the `host` block of the report).
    pub busy: Duration,
    /// Per-worker accounting merged across all batches.
    pub workers: BTreeMap<usize, WorkerSummary>,
}

impl ServeAggregate {
    /// Fold one micro-batch's result into the running totals.
    pub fn merge(&mut self, result: &BatchResult) {
        self.batches += 1;
        self.images += result.images.len() as u64;
        self.cycles += result.cycles;
        self.stats.merge(&result.stats);
        let layers = result.per_layer();
        if self.layers.is_empty() {
            self.layers = layers;
        } else {
            for (m, l) in self.layers.iter_mut().zip(&layers) {
                m.merge(l);
            }
        }
        let per_pe = result.per_pe();
        if self.per_pe.is_empty() {
            self.per_pe = per_pe;
        } else {
            for (m, s) in self.per_pe.iter_mut().zip(&per_pe) {
                m.merge(s);
            }
        }
        self.busy += result.wall;
        for w in result.worker_summaries() {
            let slot = self.workers.entry(w.worker).or_default();
            slot.worker = w.worker;
            slot.images += w.images;
            slot.busy_ns += w.busy_ns;
        }
    }

    /// Per-worker summaries sorted by worker index.
    pub fn worker_summaries(&self) -> Vec<WorkerSummary> {
        self.workers.values().copied().collect()
    }

    /// Mean images per executed micro-batch (the realized occupancy).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.images as f64 / self.batches as f64
        }
    }
}

/// The micro-batching loop (see [module docs](self)).
pub struct Batcher {
    exec: Arc<BatchExecutor>,
    queue: Arc<BoundedQueue>,
    registry: Arc<MetricsRegistry>,
    max_batch: usize,
    max_wait: Duration,
    shedder: Shedder,
    lane: u64,
    completed: Counter,
    failed: Counter,
    occupancy: Histogram,
    queue_us: Histogram,
    batch_us: Histogram,
    total_us: Histogram,
    queue_win: WindowHistogram,
    total_win: WindowHistogram,
}

impl Batcher {
    /// Build a batcher over a shared executor and admission queue,
    /// registering its instruments in `registry`.
    pub fn new(
        exec: Arc<BatchExecutor>,
        queue: Arc<BoundedQueue>,
        registry: Arc<MetricsRegistry>,
        max_batch: usize,
        max_wait: Duration,
    ) -> Self {
        assert!(max_batch > 0, "max_batch must be positive");
        Batcher {
            shedder: Shedder::new(&registry),
            lane: flight::lane_id(""),
            completed: registry.counter("serve.completed"),
            failed: registry.counter("serve.failed"),
            occupancy: registry.histogram("serve.batch_occupancy"),
            queue_us: registry.histogram("serve.latency_us.queue"),
            batch_us: registry.histogram("serve.latency_us.batch"),
            total_us: registry.histogram("serve.latency_us.total"),
            queue_win: registry.window_histogram("serve.latency_us.queue"),
            total_win: registry.window_histogram("serve.latency_us.total"),
            exec,
            queue,
            registry,
            max_batch,
            max_wait,
        }
    }

    /// Tag this batcher's flight events (dequeue/seal/execute/respond, and
    /// the shedder's sheds) with an interned lane id.
    pub fn with_lane(mut self, lane: u64) -> Self {
        self.lane = lane;
        self.shedder = self.shedder.with_lane(lane);
        self
    }

    /// Run until the queue is closed *and* drained, then return the
    /// engine-side aggregates. Every dequeued request is answered exactly
    /// once: shed, completed, or failed.
    pub fn run(&self) -> ServeAggregate {
        let mut agg = ServeAggregate::default();
        loop {
            let batch = self.queue.next_batch(self.max_batch, self.max_wait);
            if batch.is_empty() {
                return agg; // closed and fully drained
            }
            let dequeued = Instant::now();
            let rec = flight::recorder();
            for r in &batch {
                rec.record(FlightStage::Dequeue, r.flight, r.id, self.lane, 0);
            }
            let live = self.shedder.shed_expired(batch, dequeued);
            if live.is_empty() {
                continue;
            }
            self.occupancy.observe(live.len() as u64);
            let batch_id = flight::next_batch_id();
            for r in &live {
                rec.record(FlightStage::BatchSeal, r.flight, r.id, self.lane, batch_id);
            }
            let req = BatchRequest::new(live.iter().map(|r| r.image.clone()).collect());
            match self.exec.run(&req) {
                Ok(result) => {
                    self.exec.publish_to(&self.registry, &result);
                    let batch_us = result.wall.as_micros() as u64;
                    self.batch_us.observe(batch_us);
                    let done = Instant::now();
                    for (r, img) in live.iter().zip(&result.images) {
                        rec.record(FlightStage::Execute, r.flight, r.id, self.lane, batch_id);
                        let queue_us = (dequeued - r.enqueued).as_micros() as u64;
                        let total_us = (done - r.enqueued).as_micros() as u64;
                        self.queue_us.observe(queue_us);
                        self.total_us.observe(total_us);
                        self.queue_win.observe(queue_us);
                        self.total_win.observe(total_us);
                        self.completed.inc();
                        let resp = ServeResponse::ok(
                            r.id,
                            img.class,
                            img.scores.clone(),
                            live.len(),
                            queue_us,
                            batch_us,
                            total_us,
                        );
                        let _ = r.resp.send(resp.to_json_line());
                        rec.record(FlightStage::Respond, r.flight, r.id, self.lane, batch_id);
                    }
                    agg.merge(&result);
                }
                Err(e) => {
                    // Engine failure: every request in the batch is
                    // answered (and counted) as failed — never dropped.
                    let msg = format!("execution failed: {e:#}");
                    for r in &live {
                        self.failed.inc();
                        let _ = r.resp.send(ServeResponse::error(r.id, &msg).to_json_line());
                        rec.record(FlightStage::Respond, r.flight, r.id, self.lane, batch_id);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::tensor::BitTensor;
    use crate::bnn::Model;
    use crate::serve::protocol::Status;
    use crate::serve::queue::BackpressurePolicy;
    use std::sync::mpsc::channel;

    fn tiny_exec() -> Arc<BatchExecutor> {
        let model = Model::demo("tiny8").unwrap();
        Arc::new(BatchExecutor::for_model(&model).unwrap().with_array(1, 4))
    }

    #[test]
    fn batcher_drains_replies_and_aggregates() {
        let exec = tiny_exec();
        let reg = Arc::new(MetricsRegistry::new());
        let queue = Arc::new(BoundedQueue::new(8, BackpressurePolicy::Block, &reg));
        let batcher = Batcher::new(
            Arc::clone(&exec),
            Arc::clone(&queue),
            Arc::clone(&reg),
            4,
            Duration::from_millis(1),
        );
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            let (tx, rx) = channel();
            queue
                .push(ServeRequest {
                    id: i,
                    flight: 0,
                    image: BitTensor::random(8, 8, 4, 100 + i),
                    deadline: None,
                    enqueued: Instant::now(),
                    resp: tx,
                })
                .unwrap();
            rxs.push(rx);
        }
        queue.close();
        let agg = batcher.run();
        assert_eq!(agg.images, 3);
        assert!(agg.batches >= 1 && agg.cycles > 0);
        assert_eq!(agg.mean_occupancy(), 3.0 / agg.batches as f64);
        assert_eq!(reg.counter("serve.completed").get(), 3);
        assert_eq!(reg.histogram("serve.batch_occupancy").snapshot().count, agg.batches);
        for (i, rx) in rxs.iter().enumerate() {
            let resp = ServeResponse::parse(&rx.try_recv().expect("reply sent")).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.status, Status::Ok);
            // Bit-identical to a direct single-image run.
            let direct = exec.run_one(i, &BitTensor::random(8, 8, 4, 100 + i as u64)).unwrap();
            assert_eq!(resp.scores, direct.scores);
            assert_eq!(resp.class, Some(direct.class));
        }
    }
}

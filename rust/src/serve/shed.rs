//! Deadline-aware load shedding.
//!
//! Deadlines are enforced **at dequeue**, not at admission: a request that
//! sat in the queue past its `deadline_ms` will miss its SLO no matter how
//! fast the engine is, so running it would only steal lane slots from
//! requests that can still make theirs. The [`Shedder`] filters each
//! freshly dequeued micro-batch, replies `shed` to every expired request,
//! and counts them in `serve.shed` — shed work is *accounted*, never
//! silently dropped (the drain invariant `admitted == completed + shed +
//! failed` depends on it).

use super::protocol::ServeResponse;
use super::queue::ServeRequest;
use crate::metrics::flight::{self, FlightStage};
use crate::metrics::{Counter, MetricsRegistry};
use std::time::Instant;

/// Drops expired requests from dequeued batches (see [module docs](self)).
pub struct Shedder {
    shed: Counter,
    lane: u64,
}

impl Shedder {
    /// Build a shedder counting into `serve.shed` of `reg`.
    pub fn new(reg: &MetricsRegistry) -> Self {
        Shedder { shed: reg.counter("serve.shed"), lane: flight::lane_id("") }
    }

    /// Tag shed flight events with an interned lane id.
    pub fn with_lane(mut self, lane: u64) -> Self {
        self.lane = lane;
        self
    }

    /// Total requests shed so far.
    pub fn count(&self) -> u64 {
        self.shed.get()
    }

    /// Partition a dequeued batch: requests whose deadline has passed at
    /// `now` get a `shed` response and are counted; the survivors are
    /// returned for execution.
    pub fn shed_expired(&self, batch: Vec<ServeRequest>, now: Instant) -> Vec<ServeRequest> {
        let mut live = Vec::with_capacity(batch.len());
        for req in batch {
            match req.deadline {
                Some(d) if now > d => {
                    self.shed.inc();
                    flight::recorder().record(FlightStage::Shed, req.flight, req.id, self.lane, 0);
                    // A gone client is not an error: the reply is
                    // best-effort, the count is what must survive.
                    let _ = req.resp.send(ServeResponse::shed(req.id).to_json_line());
                }
                _ => live.push(req),
            }
        }
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::tensor::BitTensor;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn req(id: u64, deadline: Option<Instant>) -> (ServeRequest, std::sync::mpsc::Receiver<String>)
    {
        let (tx, rx) = channel();
        let r = ServeRequest {
            id,
            flight: 0,
            image: BitTensor::random(2, 2, 2, id),
            deadline,
            enqueued: Instant::now(),
            resp: tx,
        };
        (r, rx)
    }

    #[test]
    fn expired_requests_are_shed_and_counted() {
        let reg = MetricsRegistry::new();
        let shedder = Shedder::new(&reg);
        let now = Instant::now();
        let (expired, rx_expired) = req(1, Some(now - Duration::from_millis(5)));
        let (alive, _rx_alive) = req(2, Some(now + Duration::from_secs(5)));
        let (no_deadline, _rx_nd) = req(3, None);
        let live = shedder.shed_expired(vec![expired, alive, no_deadline], now);
        assert_eq!(live.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(shedder.count(), 1);
        assert_eq!(reg.counter("serve.shed").get(), 1);
        let line = rx_expired.try_recv().expect("shed response sent");
        let resp = ServeResponse::parse(&line).unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.status, super::super::protocol::Status::Shed);
    }

    #[test]
    fn shed_reply_to_gone_client_is_not_fatal() {
        let reg = MetricsRegistry::new();
        let shedder = Shedder::new(&reg);
        let now = Instant::now();
        let (expired, rx) = req(7, Some(now - Duration::from_millis(1)));
        drop(rx); // client hung up
        let live = shedder.shed_expired(vec![expired], now);
        assert!(live.is_empty());
        assert_eq!(shedder.count(), 1, "still counted");
    }
}

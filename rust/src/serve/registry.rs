//! The multi-model serve registry: name → [`Model`] → an isolated
//! executor + queue + batcher lane.
//!
//! A server hosts any number of models at once. Each loaded model gets a
//! **lane**: its own [`BatchExecutor`] (so lane packings and program
//! caches never mix), its own bounded admission queue, its own batcher
//! thread, and its own [`MetricsRegistry`] — which is what makes the
//! accounting invariant *per model*: every lane independently satisfies
//! `admitted == completed + shed + failed` at drain time, and the rolled-up
//! totals satisfy it by composition ([`ServeStats::merge`]).
//!
//! Lanes are hot-pluggable. [`ModelRegistry::load`] builds and starts a
//! lane on a live server (the wire `{"op": "load_model"}`);
//! [`ModelRegistry::unload`] retires one *drain-safe*: the lane is
//! unpublished first (new requests get `unknown model`), then its queue is
//! closed, the batcher flushes every in-flight request — each answered
//! exactly once — and only then is the final [`ModelDrain`] frozen. The
//! drained report is kept so a later [`ModelRegistry::drain_all`] still
//! accounts for every request the server ever admitted.

use super::batcher::{Batcher, ServeAggregate};
use super::queue::BoundedQueue;
use super::{ServeConfig, ServeStats};
use crate::bnn::Model;
use crate::coordinator::{BatchExecutor, PerfReport, ReportParts};
use crate::error::Error;
use crate::metrics::MetricsRegistry;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// One served model: executor, admission queue, batcher thread and scoped
/// metrics. Handed out by [`ModelRegistry::get`] for request routing.
pub struct ModelLane {
    name: String,
    exec: Arc<BatchExecutor>,
    queue: Arc<BoundedQueue>,
    metrics: Arc<MetricsRegistry>,
    batcher: Mutex<Option<JoinHandle<ServeAggregate>>>,
}

impl std::fmt::Debug for ModelLane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelLane")
            .field("name", &self.name)
            .field("model", &self.exec.model().name())
            .field("queue_depth", &self.queue.len())
            .finish()
    }
}

impl ModelLane {
    /// Registry name this lane is published under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The served model.
    pub fn model(&self) -> &Model {
        self.exec.model()
    }

    /// The lane's admission queue (where routed requests are pushed).
    pub fn queue(&self) -> &Arc<BoundedQueue> {
        &self.queue
    }

    /// The lane's scoped metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Point-in-time accounting snapshot for this lane.
    pub fn stats(&self) -> ServeStats {
        ServeStats::from_registry(&self.metrics)
    }
}

/// The frozen result of draining one lane: its final accounting plus the
/// full engine-side [`PerfReport`].
#[derive(Debug)]
pub struct ModelDrain {
    /// Registry name the model was served under.
    pub name: String,
    /// Final serving-layer accounting (the invariant holds here).
    pub stats: ServeStats,
    /// Engine-side report (cycles, energy, per-layer) with the serve
    /// stats and metrics snapshot embedded.
    pub report: PerfReport,
}

/// The thread-safe name → lane map (see the [module docs](self)).
pub struct ModelRegistry {
    cfg: ServeConfig,
    /// Load order is meaningful: the first lane is the default route for
    /// requests that omit the `model` field.
    lanes: RwLock<Vec<Arc<ModelLane>>>,
    /// Drain receipts of unloaded lanes, kept for the final report.
    retired: Mutex<Vec<ModelDrain>>,
}

impl std::fmt::Debug for ModelRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRegistry").field("models", &self.names()).finish()
    }
}

impl ModelRegistry {
    /// An empty registry; `cfg` shapes every lane built by
    /// [`ModelRegistry::load`].
    pub fn new(cfg: ServeConfig) -> Self {
        ModelRegistry { cfg, lanes: RwLock::new(Vec::new()), retired: Mutex::new(Vec::new()) }
    }

    /// Names of the currently loaded models, in load order (the first is
    /// the default route).
    pub fn names(&self) -> Vec<String> {
        self.lanes.read().expect("lanes lock").iter().map(|l| l.name.clone()).collect()
    }

    /// Number of loaded models.
    pub fn len(&self) -> usize {
        self.lanes.read().expect("lanes lock").len()
    }

    /// Whether no model is currently loaded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Build and publish a lane for `model` under `name`. The executor is
    /// configured from the registry's [`ServeConfig`] (engine, array
    /// geometry, worker threads) and the lane's batcher thread starts
    /// immediately. Fails typed on a duplicate name or an unservable
    /// model — a live server survives a bad `load_model` request.
    pub fn load(&self, name: &str, model: Model) -> std::result::Result<(), Error> {
        if self.lanes.read().expect("lanes lock").iter().any(|l| l.name == name) {
            return Err(Error::DuplicateModel(name.to_string()));
        }
        // Build the lane outside the lock: packing a big model must not
        // stall request routing on other lanes.
        let mut exec = BatchExecutor::for_model(&model)?.with_engine(self.cfg.engine);
        if let Some((units, pes)) = self.cfg.array {
            exec = exec.with_array(units, pes);
        }
        if self.cfg.threads > 0 {
            exec = exec.with_threads(self.cfg.threads);
        }
        let exec = Arc::new(exec);
        let metrics = Arc::new(MetricsRegistry::new());
        let lane_id = crate::metrics::flight::lane_id(name);
        let queue = Arc::new(
            BoundedQueue::new(self.cfg.queue_cap, self.cfg.policy, &metrics).with_lane(lane_id),
        );
        let batcher = Batcher::new(
            Arc::clone(&exec),
            Arc::clone(&queue),
            Arc::clone(&metrics),
            self.cfg.max_batch,
            Duration::from_micros(self.cfg.max_wait_us),
        )
        .with_lane(lane_id);
        let handle = std::thread::Builder::new()
            .name(format!("serve-batcher-{name}"))
            .spawn(move || batcher.run())
            .expect("spawning model batcher");
        let lane = Arc::new(ModelLane {
            name: name.to_string(),
            exec,
            queue,
            metrics,
            batcher: Mutex::new(Some(handle)),
        });
        let mut lanes = self.lanes.write().expect("lanes lock");
        if lanes.iter().any(|l| l.name == name) {
            // A concurrent loader won the race; tear our lane down unused.
            drop(lanes);
            drain_lane(&lane);
            return Err(Error::DuplicateModel(name.to_string()));
        }
        lanes.push(lane);
        Ok(())
    }

    /// Route a request: `Some(name)` looks up by name, `None` takes the
    /// default (first-loaded) lane.
    pub fn get(&self, name: Option<&str>) -> std::result::Result<Arc<ModelLane>, Error> {
        let lanes = self.lanes.read().expect("lanes lock");
        match name {
            Some(n) => lanes
                .iter()
                .find(|l| l.name == n)
                .cloned()
                .ok_or_else(|| Error::UnknownModel(n.to_string())),
            None => {
                lanes.first().cloned().ok_or_else(|| Error::UnknownModel("(default)".to_string()))
            }
        }
    }

    /// Drain-safe unload: unpublish the lane, close its queue, let the
    /// batcher answer everything still in flight, and freeze the final
    /// accounting. Returns the lane's final [`ServeStats`] (on which
    /// [`ServeStats::accounted`] holds); the full [`ModelDrain`] is
    /// retained for [`ModelRegistry::drain_all`].
    pub fn unload(&self, name: &str) -> std::result::Result<ServeStats, Error> {
        let lane = {
            let mut lanes = self.lanes.write().expect("lanes lock");
            let i = lanes
                .iter()
                .position(|l| l.name == name)
                .ok_or_else(|| Error::UnknownModel(name.to_string()))?;
            lanes.remove(i)
        };
        let drain = drain_lane(&lane);
        let stats = drain.stats.clone();
        self.retired.lock().expect("retired lock").push(drain);
        Ok(stats)
    }

    /// Drain every remaining lane and return all drain receipts — retired
    /// lanes first, then live ones — so the final report accounts for
    /// every request the server ever admitted.
    pub fn drain_all(&self) -> Vec<ModelDrain> {
        let lanes: Vec<Arc<ModelLane>> =
            std::mem::take(&mut *self.lanes.write().expect("lanes lock"));
        let mut out = std::mem::take(&mut *self.retired.lock().expect("retired lock"));
        out.extend(lanes.iter().map(drain_lane));
        out
    }

    /// The live lanes' scoped metrics registries as `(name, registry)`,
    /// in load order — what the Prometheus exposition renders with a
    /// `model="<name>"` label per lane. Unloaded lanes are absent (their
    /// registry `Arc` is dropped with the lane), so hot load/unload
    /// cycles cannot leak metric cardinality into the scrape.
    pub fn lane_metrics(&self) -> Vec<(String, Arc<MetricsRegistry>)> {
        self.lanes
            .read()
            .expect("lanes lock")
            .iter()
            .map(|l| (l.name.clone(), Arc::clone(&l.metrics)))
            .collect()
    }

    /// Server-wide accounting right now: live lanes plus already-retired
    /// ones (so totals never go backwards when a model is unloaded).
    pub fn total_stats(&self) -> ServeStats {
        let mut total = ServeStats::default();
        for lane in self.lanes.read().expect("lanes lock").iter() {
            total.merge(&lane.stats());
        }
        for d in self.retired.lock().expect("retired lock").iter() {
            total.merge(&d.stats);
        }
        total
    }

    /// The reply to the wire `{"op": "stats"}`: rolled-up totals plus a
    /// per-model breakdown of the currently loaded lanes.
    pub fn stats_line(&self) -> String {
        let per_model: Vec<String> = self
            .lanes
            .read()
            .expect("lanes lock")
            .iter()
            .map(|l| {
                format!(
                    "{{\"name\": {}, {}}}",
                    super::protocol::json_str(&l.name),
                    l.stats().json_fields()
                )
            })
            .collect();
        format!(
            "{{\"op\": \"stats\", {}, \"models\": [{}]}}",
            self.total_stats().json_fields(),
            per_model.join(", ")
        )
    }
}

/// Close a lane's queue, join its batcher (which answers everything still
/// queued, exactly once), then freeze accounting and the perf report.
/// Ordering is what makes the invariant hold: the stats snapshot happens
/// strictly after the batcher exits.
fn drain_lane(lane: &Arc<ModelLane>) -> ModelDrain {
    lane.queue.close();
    let handle = lane.batcher.lock().expect("batcher lock").take();
    let agg = match handle {
        Some(h) => h.join().expect("model batcher panicked"),
        None => ServeAggregate::default(),
    };
    let stats = ServeStats::from_registry(&lane.metrics);
    let parts = ReportParts {
        batch: agg.images as usize,
        wall: agg.busy,
        cycles: agg.cycles,
        stats: agg.stats,
        layers: agg.layers.clone(),
        per_pe: agg.per_pe.clone(),
        workers: agg.worker_summaries(),
    };
    let report = PerfReport::from_parts(&lane.exec, parts)
        .with_serve(stats.clone())
        .with_metrics(lane.metrics.snapshot());
    ModelDrain { name: lane.name.clone(), stats, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::tensor::BitTensor;
    use crate::serve::queue::ServeRequest;
    use crate::serve::ServeResponse;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn small_cfg() -> ServeConfig {
        ServeConfig::builder().max_batch(4).max_wait_us(200).queue_cap(16).array(1, 4).build()
    }

    #[test]
    fn load_route_and_duplicate_are_typed() {
        let reg = ModelRegistry::new(small_cfg());
        assert!(reg.is_empty());
        reg.load("a", Model::demo("tiny8").unwrap()).unwrap();
        reg.load("b", Model::demo("tiny").unwrap()).unwrap();
        assert_eq!(reg.names(), vec!["a", "b"]);
        // Default route is the first-loaded lane.
        assert_eq!(reg.get(None).unwrap().name(), "a");
        assert_eq!(reg.get(Some("b")).unwrap().model().input_dims(), (16, 16, 8));
        match reg.get(Some("zzz")) {
            Err(Error::UnknownModel(n)) => assert_eq!(n, "zzz"),
            other => panic!("expected UnknownModel, got {other:?}"),
        }
        match reg.load("a", Model::demo("tiny8").unwrap()) {
            Err(Error::DuplicateModel(n)) => assert_eq!(n, "a"),
            other => panic!("expected DuplicateModel, got {other:?}"),
        }
        for d in reg.drain_all() {
            assert!(d.stats.accounted());
        }
    }

    #[test]
    fn unload_is_drain_safe_and_accounted() {
        let reg = ModelRegistry::new(small_cfg());
        reg.load("t8", Model::demo("tiny8").unwrap()).unwrap();
        let lane = reg.get(Some("t8")).unwrap();
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            let (tx, rx) = channel();
            lane.queue()
                .push(ServeRequest {
                    id: i,
                    flight: 0,
                    image: BitTensor::random(8, 8, 4, 40 + i),
                    deadline: None,
                    enqueued: Instant::now(),
                    resp: tx,
                })
                .unwrap();
            rxs.push(rx);
        }
        // Unload with requests in flight: all three must still be answered.
        let stats = reg.unload("t8").unwrap();
        assert!(stats.accounted(), "unload must leave zero accounting discrepancy");
        assert_eq!(stats.admitted, 3);
        assert_eq!(stats.completed, 3);
        for rx in &rxs {
            let resp = ServeResponse::parse(&rx.try_recv().expect("answered")).unwrap();
            assert_eq!(resp.status, crate::serve::Status::Ok);
        }
        // The lane is unpublished; its numbers survive in the totals.
        assert!(matches!(reg.get(Some("t8")), Err(Error::UnknownModel(_))));
        assert_eq!(reg.total_stats().completed, 3);
        let drains = reg.drain_all();
        assert_eq!(drains.len(), 1);
        assert_eq!(drains[0].name, "t8");
        assert_eq!(drains[0].report.batch as u64, 3);
    }

    #[test]
    fn lane_metrics_retire_with_the_lane() {
        let reg = ModelRegistry::new(small_cfg());
        reg.load("m1", Model::demo("tiny8").unwrap()).unwrap();
        reg.load("m2", Model::demo("tiny").unwrap()).unwrap();
        let names: Vec<String> = reg.lane_metrics().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["m1", "m2"]);
        // Unloading drops the lane's registry from the exposition set.
        reg.unload("m1").unwrap();
        let names: Vec<String> = reg.lane_metrics().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["m2"]);
        reg.drain_all();
    }

    #[test]
    fn stats_line_breaks_out_models() {
        let reg = ModelRegistry::new(small_cfg());
        reg.load("x", Model::demo("tiny8").unwrap()).unwrap();
        let line = reg.stats_line();
        assert!(line.contains("\"op\": \"stats\""), "{line}");
        assert!(line.contains("\"models\": [{\"name\": \"x\""), "{line}");
        reg.drain_all();
    }
}

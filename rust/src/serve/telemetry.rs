//! The live-telemetry HTTP endpoint (`--metrics-addr`).
//!
//! A std-only, hand-rolled HTTP/1.1 server in the same spirit as the
//! JSON-lines wire protocol: no framework, one short-lived connection per
//! scrape. Four routes:
//!
//! | Route      | Serves                                                   |
//! |------------|----------------------------------------------------------|
//! | `/metrics` | Prometheus text exposition — the global registry plus every live model lane (`model="<name>"` label) |
//! | `/healthz` | Liveness: `200 ok` while the process runs                |
//! | `/readyz`  | Readiness: `200 ready` iff ≥ 1 lane is published and the server is not draining, else `503` |
//! | `/trace`   | The flight recorder as one `tulip.trace/v1` JSON document |
//!
//! The endpoint is started by [`serve`](super::server::serve) when
//! [`ServeConfig::metrics_addr`](super::ServeConfig) is set, and the loop
//! exits with the server's drain (the handle is joined by
//! [`ServeHandle::drain`](super::server::ServeHandle::drain)).

use super::registry::ModelRegistry;
use crate::metrics::{flight, prometheus, MetricsRegistry};
use crate::Result;
use anyhow::Context;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Prometheus text exposition content type (format 0.0.4).
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// A running telemetry endpoint (see the [module docs](self)).
#[derive(Debug)]
pub struct TelemetryHandle {
    addr: SocketAddr,
    thread: JoinHandle<()>,
}

impl TelemetryHandle {
    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the serve loop to exit (it does so once its drain flag —
    /// shared with the owning server — is raised).
    pub fn join(self) {
        let _ = self.thread.join();
    }
}

/// Bind `addr` and start answering telemetry requests on a background
/// thread. Readiness tracks `models` (≥ 1 lane published) and `draining`;
/// the loop exits when `draining` (or a process-wide signal drain) is
/// raised.
pub fn start(
    addr: &str,
    models: Arc<ModelRegistry>,
    draining: Arc<AtomicBool>,
) -> Result<TelemetryHandle> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding telemetry endpoint {addr}"))?;
    listener.set_nonblocking(true).context("nonblocking telemetry listener")?;
    let bound = listener.local_addr().context("telemetry local addr")?;
    let thread = std::thread::Builder::new()
        .name("serve-telemetry".into())
        .spawn(move || {
            while !super::server::signal_drain_requested() && !draining.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let ready = !draining.load(Ordering::SeqCst) && !models.is_empty();
                        // Scrapes are small and rare; serving them inline
                        // keeps the endpoint a single thread.
                        let _ = handle_request(stream, &models, ready);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(5)),
                }
            }
        })
        .context("spawning telemetry loop")?;
    Ok(TelemetryHandle { addr: bound, thread })
}

/// Read one request, answer it, close the connection.
fn handle_request(stream: TcpStream, models: &ModelRegistry, ready: bool) -> Result<()> {
    stream.set_nonblocking(false).context("blocking telemetry stream")?;
    stream.set_read_timeout(Some(Duration::from_secs(2))).context("telemetry read timeout")?;
    stream.set_write_timeout(Some(Duration::from_secs(2))).context("telemetry write timeout")?;
    let mut reader = BufReader::new(stream);
    let mut request = String::new();
    reader.read_line(&mut request).context("reading request line")?;
    // Drain the headers; we key off the request line alone.
    loop {
        let mut header = String::new();
        let n = reader.read_line(&mut header).context("reading header")?;
        if n == 0 || header == "\r\n" || header == "\n" {
            break;
        }
    }
    let mut parts = request.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("").split('?').next().unwrap_or("");
    let stream = reader.into_inner();
    if method != "GET" {
        return respond(stream, "405 Method Not Allowed", "text/plain", "method not allowed\n");
    }
    match path {
        "/metrics" => {
            let body = prometheus::render(MetricsRegistry::global(), &models.lane_metrics());
            respond(stream, "200 OK", PROMETHEUS_CONTENT_TYPE, &body)
        }
        "/healthz" => respond(stream, "200 OK", "text/plain", "ok\n"),
        "/readyz" if ready => respond(stream, "200 OK", "text/plain", "ready\n"),
        "/readyz" => {
            respond(stream, "503 Service Unavailable", "text/plain", "not ready\n")
        }
        "/trace" => {
            let body = format!("{}\n", flight::recorder().snapshot().to_json_line());
            respond(stream, "200 OK", "application/json", &body)
        }
        _ => respond(stream, "404 Not Found", "text/plain", "not found\n"),
    }
}

/// Write a complete `HTTP/1.1` response and flush.
fn respond(mut stream: TcpStream, status: &str, content_type: &str, body: &str) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).context("writing response head")?;
    stream.write_all(body.as_bytes()).context("writing response body")?;
    stream.flush().context("flushing response")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ServeConfig;
    use std::io::Read;

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        let (head, body) = buf.split_once("\r\n\r\n").expect("complete response");
        (head.to_string(), body.to_string())
    }

    #[test]
    fn endpoint_serves_probes_metrics_and_trace() {
        let models = Arc::new(ModelRegistry::new(ServeConfig::default()));
        let draining = Arc::new(AtomicBool::new(false));
        let handle = start("127.0.0.1:0", Arc::clone(&models), Arc::clone(&draining)).unwrap();
        let addr = handle.local_addr();

        let (head, body) = http_get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(body, "ok\n");

        // No lane published yet → not ready.
        let (head, body) = http_get(addr, "/readyz");
        assert!(head.starts_with("HTTP/1.1 503"), "{head}");
        assert_eq!(body, "not ready\n");

        let (head, body) = http_get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("version=0.0.4"), "{head}");
        crate::metrics::check_exposition(&body).unwrap();

        let (head, body) = http_get(addr, "/trace");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(body.contains("tulip.trace/v1"), "{body}");

        let (head, _) = http_get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        // Raising the drain flag stops the loop.
        draining.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // nudge past the accept sleep
        handle.join();
    }
}

//! Bounded admission queue with configurable backpressure.
//!
//! The queue is the single hand-off point between connection readers and
//! the batcher. It is bounded (`cap`) so a traffic burst turns into
//! *explicit* backpressure instead of unbounded memory growth: under
//! [`BackpressurePolicy::Block`] producers wait for space (never exceeding
//! capacity), under [`BackpressurePolicy::Reject`] a full queue returns the
//! request to the caller for a 429-style `rejected` reply.
//!
//! Admission accounting happens here: every successful [`BoundedQueue::push`]
//! bumps `serve.admitted`, every refusal bumps `serve.rejected`, and the
//! `serve.queue_depth` gauge tracks occupancy.

use crate::bnn::tensor::BitTensor;
use crate::metrics::flight::{self, FlightStage};
use crate::metrics::{Counter, Gauge, MetricsRegistry};
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted inference request flowing from a connection reader to the
/// batcher. Carries its own response channel so the batcher can reply
/// without knowing anything about sockets.
#[derive(Debug)]
pub struct ServeRequest {
    /// Client-chosen request id, echoed on the response.
    pub id: u64,
    /// Process-unique flight-recorder id, assigned by
    /// [`BoundedQueue::push`] at admission (0 before admission).
    pub flight: u64,
    /// The unpacked input image.
    pub image: BitTensor,
    /// Absolute shed deadline, if the client set `deadline_ms`.
    pub deadline: Option<Instant>,
    /// When the request was admitted (for queue-latency accounting).
    pub enqueued: Instant,
    /// Where to send the encoded response line.
    pub resp: Sender<String>,
}

/// What to do with a new request when the queue is at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackpressurePolicy {
    /// Block the connection reader until space frees up (per-connection
    /// backpressure; the queue never exceeds capacity).
    #[default]
    Block,
    /// Refuse immediately with a `rejected` response (429-style).
    Reject,
}

impl BackpressurePolicy {
    /// CLI/wire name.
    pub fn name(self) -> &'static str {
        match self {
            BackpressurePolicy::Block => "block",
            BackpressurePolicy::Reject => "reject",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "block" => Some(BackpressurePolicy::Block),
            "reject" => Some(BackpressurePolicy::Reject),
            _ => None,
        }
    }
}

/// Why a push failed; the request is handed back for the reply.
#[derive(Debug)]
pub enum PushError {
    /// Queue at capacity under [`BackpressurePolicy::Reject`].
    Full(ServeRequest),
    /// The queue was closed (server draining) — no new admissions.
    Closed(ServeRequest),
}

struct Inner {
    items: VecDeque<ServeRequest>,
    closed: bool,
}

/// The bounded, policy-aware admission queue.
pub struct BoundedQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    policy: BackpressurePolicy,
    lane: u64,
    depth: Gauge,
    admitted: Counter,
    rejected: Counter,
}

impl BoundedQueue {
    /// Build a queue of the given capacity, registering its metrics
    /// (`serve.queue_depth`, `serve.admitted`, `serve.rejected`) in `reg`.
    pub fn new(cap: usize, policy: BackpressurePolicy, reg: &MetricsRegistry) -> Self {
        assert!(cap > 0, "queue capacity must be positive");
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(cap), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
            policy,
            lane: flight::lane_id(""),
            depth: reg.gauge("serve.queue_depth"),
            admitted: reg.counter("serve.admitted"),
            rejected: reg.counter("serve.rejected"),
        }
    }

    /// Tag admissions with an interned flight-recorder lane id (see
    /// [`flight::lane_id`]); the serve registry sets this to the model
    /// lane's name.
    pub fn with_lane(mut self, lane: u64) -> Self {
        self.lane = lane;
        self
    }

    /// Maximum number of queued requests.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current queue depth.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue lock").items.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit a request, applying the backpressure policy when full. On
    /// success the request is issued its flight id and the admission is
    /// recorded in the global flight recorder.
    pub fn push(&self, mut req: ServeRequest) -> Result<(), PushError> {
        let mut inner = self.inner.lock().expect("queue lock");
        if inner.closed {
            self.rejected.inc();
            return Err(PushError::Closed(req));
        }
        while inner.items.len() >= self.cap {
            match self.policy {
                BackpressurePolicy::Reject => {
                    self.rejected.inc();
                    return Err(PushError::Full(req));
                }
                BackpressurePolicy::Block => {
                    inner = self.not_full.wait(inner).expect("queue lock");
                    if inner.closed {
                        self.rejected.inc();
                        return Err(PushError::Closed(req));
                    }
                }
            }
        }
        req.flight = flight::next_flight_id();
        flight::recorder().record(FlightStage::Admit, req.flight, req.id, self.lane, 0);
        inner.items.push_back(req);
        self.admitted.inc();
        self.depth.set(inner.items.len() as f64);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue the next micro-batch: wait (forever) for at least one
    /// request, then gather more until `max_batch` items are in hand or
    /// `max_wait` has elapsed since the *first* dequeue, whichever comes
    /// first. Returns an empty vec only when the queue is closed **and**
    /// fully drained — the batcher's signal to exit.
    pub fn next_batch(&self, max_batch: usize, max_wait: Duration) -> Vec<ServeRequest> {
        let mut inner = self.inner.lock().expect("queue lock");
        // Phase 1: wait for the first request (or close+drain).
        while inner.items.is_empty() {
            if inner.closed {
                return Vec::new();
            }
            inner = self.not_empty.wait(inner).expect("queue lock");
        }
        let mut batch = Vec::with_capacity(max_batch.min(inner.items.len()));
        batch.push(inner.items.pop_front().expect("non-empty"));
        let flush_at = Instant::now() + max_wait;
        // Phase 2: top up until full or the wait budget is spent. Once the
        // queue closes there is no reason to linger — take what's there.
        loop {
            while batch.len() < max_batch {
                match inner.items.pop_front() {
                    Some(req) => batch.push(req),
                    None => break,
                }
            }
            if batch.len() >= max_batch || inner.closed {
                break;
            }
            let now = Instant::now();
            if now >= flush_at {
                break;
            }
            let (guard, timeout) =
                self.not_empty.wait_timeout(inner, flush_at - now).expect("queue lock");
            inner = guard;
            if timeout.timed_out() && inner.items.is_empty() {
                break;
            }
        }
        self.depth.set(inner.items.len() as f64);
        self.not_full.notify_all();
        batch
    }

    /// Close the queue: refuse all future pushes, wake every waiter. Queued
    /// requests remain and will still be drained by [`Self::next_batch`].
    pub fn close(&self) {
        let mut inner = self.inner.lock().expect("queue lock");
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`Self::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("queue lock").closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(id: u64) -> (ServeRequest, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = channel();
        let r = ServeRequest {
            id,
            flight: 0,
            image: BitTensor::random(2, 2, 2, id),
            deadline: None,
            enqueued: Instant::now(),
            resp: tx,
        };
        (r, rx)
    }

    #[test]
    fn reject_policy_refuses_when_full() {
        let reg = MetricsRegistry::new();
        let q = BoundedQueue::new(2, BackpressurePolicy::Reject, &reg);
        assert!(q.push(req(1).0).is_ok());
        assert!(q.push(req(2).0).is_ok());
        match q.push(req(3).0) {
            Err(PushError::Full(r)) => assert_eq!(r.id, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(reg.counter("serve.admitted").get(), 2);
        assert_eq!(reg.counter("serve.rejected").get(), 1);
    }

    #[test]
    fn block_policy_never_exceeds_capacity() {
        let reg = MetricsRegistry::new();
        let q = Arc::new(BoundedQueue::new(2, BackpressurePolicy::Block, &reg));
        for i in 0..2 {
            q.push(req(i).0).unwrap();
        }
        // A third push must block until the consumer makes room.
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || q2.push(req(99).0).map_err(|_| "refused"));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 2, "blocked producer must not overfill");
        let batch = q.next_batch(1, Duration::from_millis(1));
        assert_eq!(batch.len(), 1);
        producer.join().unwrap().unwrap();
        assert!(q.len() <= 2);
        assert_eq!(reg.counter("serve.admitted").get(), 3);
    }

    #[test]
    fn next_batch_flushes_on_max_batch() {
        let reg = MetricsRegistry::new();
        let q = BoundedQueue::new(16, BackpressurePolicy::Block, &reg);
        for i in 0..5 {
            q.push(req(i).0).unwrap();
        }
        // max_wait is generous, but max_batch=3 flushes immediately.
        let b = q.next_batch(3, Duration::from_secs(5));
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let b = q.next_batch(3, Duration::from_millis(1));
        assert_eq!(b.len(), 2, "partial flush on max_wait");
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let reg = MetricsRegistry::new();
        let q = BoundedQueue::new(4, BackpressurePolicy::Reject, &reg);
        q.push(req(1).0).unwrap();
        q.close();
        match q.push(req(2).0) {
            Err(PushError::Closed(r)) => assert_eq!(r.id, 2),
            other => panic!("expected Closed, got {other:?}"),
        }
        // Residual items still drain…
        let b = q.next_batch(8, Duration::from_millis(1));
        assert_eq!(b.len(), 1);
        // …then the empty vec signals exit, without blocking.
        assert!(q.next_batch(8, Duration::from_secs(5)).is_empty());
    }
}

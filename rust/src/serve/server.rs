//! The TCP front-end: connection handling, admission, graceful drain.
//!
//! [`serve`] binds a listener and returns a [`ServeHandle`] immediately —
//! the accept loop and the batcher run on background threads. Each
//! connection gets a reader (parses request lines, pushes into the
//! admission queue) and a writer thread (drains an `mpsc` channel of
//! encoded response lines), so responses from the batcher never block the
//! engine on a slow client socket.
//!
//! Shutdown is graceful by construction: a `{"op": "drain"}` control
//! message — or SIGTERM/ctrl-c via [`request_drain`] — stops the accept
//! loop and closes the queue; the batcher then flushes everything still
//! queued (deadline sheds still apply), and [`ServeHandle::drain`] joins
//! the threads and freezes the final [`PerfReport`].

use super::batcher::Batcher;
use super::protocol::{parse_client_msg, ClientMsg, ServeResponse};
use super::queue::{BoundedQueue, PushError, ServeRequest};
use super::{ServeConfig, ServeStats};
use crate::coordinator::{BatchExecutor, PerfReport, ReportParts};
use crate::metrics::MetricsRegistry;
use crate::Result;
use anyhow::Context;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Process-wide drain flag set by the CLI's SIGTERM/SIGINT handlers (a
/// signal means the whole process is going down, so *every* server in the
/// process honors it). Programmatic drains — the wire `{"op": "drain"}` or
/// [`ServeHandle::drain`] — use a per-server flag instead, so concurrent
/// servers (e.g. parallel tests) never drain each other.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Request a process-wide graceful drain (what the signal handlers call).
pub fn request_drain() {
    SIGNAL_DRAIN.store(true, Ordering::SeqCst);
}

/// A running server: background accept loop + batcher, plus everything
/// needed to account for and report on them at drain time.
pub struct ServeHandle {
    addr: SocketAddr,
    exec: Arc<BatchExecutor>,
    queue: Arc<BoundedQueue>,
    registry: Arc<MetricsRegistry>,
    draining: Arc<AtomicBool>,
    accept: JoinHandle<()>,
    batcher: JoinHandle<super::batcher::ServeAggregate>,
    started: Instant,
}

impl ServeHandle {
    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// This server's scoped metrics registry.
    pub fn registry(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    /// Whether a drain has been requested (by signal, wire, or handle).
    pub fn drain_requested(&self) -> bool {
        SIGNAL_DRAIN.load(Ordering::SeqCst)
            || self.draining.load(Ordering::SeqCst)
            || self.queue.is_closed()
    }

    /// Block until a drain is requested, polling the flags.
    pub fn wait_for_drain(&self) {
        while !self.drain_requested() {
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Gracefully drain: stop accepting, flush the queue through the
    /// batcher (deadline sheds still apply), join the background threads,
    /// and freeze the final report. The returned [`PerfReport`] carries
    /// the [`ServeStats`] accounting — `admitted == completed + shed +
    /// failed` holds at this point, every admitted request answered.
    pub fn drain(self) -> Result<PerfReport> {
        self.draining.store(true, Ordering::SeqCst);
        self.queue.close();
        self.accept.join().map_err(|_| anyhow::anyhow!("accept loop panicked"))?;
        let agg = self.batcher.join().map_err(|_| anyhow::anyhow!("batcher panicked"))?;
        let uptime = self.started.elapsed();
        let parts = ReportParts {
            batch: agg.images as usize,
            wall: agg.busy,
            cycles: agg.cycles,
            stats: agg.stats,
            layers: agg.layers.clone(),
            per_pe: agg.per_pe.clone(),
            workers: agg.worker_summaries(),
        };
        let stats = ServeStats::from_registry(&self.registry);
        self.registry.gauge("serve.uptime_ms").set(uptime.as_secs_f64() * 1e3);
        Ok(PerfReport::from_parts(&self.exec, parts)
            .with_serve(stats)
            .with_metrics(self.registry.snapshot()))
    }
}

/// Bind and start serving. Returns as soon as the listener is bound; use
/// the returned handle to wait and drain.
pub fn serve(exec: BatchExecutor, cfg: ServeConfig) -> Result<ServeHandle> {
    let exec = Arc::new(exec);
    let registry = Arc::new(MetricsRegistry::new());
    let queue = Arc::new(BoundedQueue::new(cfg.queue_cap, cfg.policy, &registry));
    let draining = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let addr = listener.local_addr().context("local addr")?;

    let batcher = Batcher::new(
        Arc::clone(&exec),
        Arc::clone(&queue),
        Arc::clone(&registry),
        cfg.max_batch,
        Duration::from_micros(cfg.max_wait_us),
    );
    let batcher = std::thread::Builder::new()
        .name("serve-batcher".into())
        .spawn(move || batcher.run())
        .context("spawning batcher")?;

    let accept = {
        let exec = Arc::clone(&exec);
        let queue = Arc::clone(&queue);
        let registry = Arc::clone(&registry);
        let draining = Arc::clone(&draining);
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, exec, queue, registry, draining))
            .context("spawning accept loop")?
    };

    Ok(ServeHandle {
        addr,
        exec,
        queue,
        registry,
        draining,
        accept,
        batcher,
        started: Instant::now(),
    })
}

/// Poll-accept until a drain is requested (nonblocking listener + short
/// sleep, so the loop notices the flags without a connection arriving).
fn accept_loop(
    listener: TcpListener,
    exec: Arc<BatchExecutor>,
    queue: Arc<BoundedQueue>,
    registry: Arc<MetricsRegistry>,
    draining: Arc<AtomicBool>,
) {
    let connections = registry.gauge("serve.connections");
    while !SIGNAL_DRAIN.load(Ordering::SeqCst)
        && !draining.load(Ordering::SeqCst)
        && !queue.is_closed()
    {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let exec = Arc::clone(&exec);
                let queue = Arc::clone(&queue);
                let registry = Arc::clone(&registry);
                let draining = Arc::clone(&draining);
                let connections = connections.clone();
                connections.inc();
                let _ = std::thread::Builder::new().name("serve-conn".into()).spawn(move || {
                    let _ = handle_connection(stream, &exec, &queue, &registry, &draining);
                    connections.dec();
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Spawn the writer thread for one connection: drains encoded response
/// lines from `rx` into the socket. Exits when every `Sender` clone is
/// gone (reader done *and* no request of this connection still queued).
fn spawn_writer(stream: TcpStream, rx: Receiver<String>) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("serve-writer".into())
        .spawn(move || {
            let mut w = BufWriter::new(stream);
            while let Ok(line) = rx.recv() {
                if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                    break; // client gone; replies are best-effort
                }
                let _ = w.flush();
            }
        })
        .expect("spawning connection writer")
}

/// One connection's reader: parse request lines, admit them, reply
/// directly on protocol/admission errors.
fn handle_connection(
    stream: TcpStream,
    exec: &BatchExecutor,
    queue: &BoundedQueue,
    registry: &MetricsRegistry,
    draining: &AtomicBool,
) -> Result<()> {
    let l0 = &exec.network().layers[0];
    let input = (l0.y1, l0.x1, l0.z1);
    let write_stream = stream.try_clone().context("cloning stream for writer")?;
    let (tx, rx): (Sender<String>, Receiver<String>) = channel();
    let writer = spawn_writer(write_stream, rx);
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // connection reset
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_client_msg(&line, input) {
            Ok(ClientMsg::Infer(req)) => {
                let (h, w, c) = input;
                let deadline =
                    req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
                let sreq = ServeRequest {
                    id: req.id,
                    image: req.image(h, w, c),
                    deadline,
                    enqueued: Instant::now(),
                    resp: tx.clone(),
                };
                match queue.push(sreq) {
                    Ok(()) => {}
                    Err(PushError::Full(r)) => {
                        let _ = tx.send(ServeResponse::rejected(r.id, "queue full").to_json_line());
                    }
                    Err(PushError::Closed(r)) => {
                        let _ = tx
                            .send(ServeResponse::rejected(r.id, "server draining").to_json_line());
                    }
                }
            }
            Ok(ClientMsg::Stats) => {
                let _ = tx.send(ServeStats::from_registry(registry).to_json_line());
            }
            Ok(ClientMsg::Drain) => {
                let _ = tx.send("{\"op\": \"drain\", \"ack\": true}".to_string());
                draining.store(true, Ordering::SeqCst);
                queue.close();
                break;
            }
            Err(e) => {
                let _ = tx.send(ServeResponse::error(e.id, &e.msg).to_json_line());
            }
        }
    }
    // Drop our sender; the writer exits once queued requests (which hold
    // clones) have been answered and released by the batcher.
    drop(tx);
    let _ = writer.join();
    Ok(())
}

//! The TCP front-end: connection handling, model routing, graceful drain.
//!
//! [`serve`] loads the given models into a [`ModelRegistry`], binds a
//! listener and returns a [`ServeHandle`] immediately — the accept loop
//! and every model's batcher run on background threads. Each connection
//! gets a reader (parses request lines, routes them to a model lane by
//! the request's `model` field, pushes into that lane's admission queue)
//! and a writer thread (drains an `mpsc` channel of encoded response
//! lines), so responses from the batchers never block an engine on a slow
//! client socket.
//!
//! Models are hot-pluggable over the wire: `{"op": "load_model"}` decodes
//! an inline `tulip.model/v1` document and publishes a new lane;
//! `{"op": "unload_model"}` retires one drain-safe — in-flight requests
//! are answered first, and the reply carries the lane's final counters
//! with an `"accounted"` verdict.
//!
//! Shutdown is graceful by construction: a `{"op": "drain"}` control
//! message — or SIGTERM/ctrl-c via [`request_drain`] — stops the accept
//! loop; [`ServeHandle::drain`] then closes every lane's queue, the
//! batchers flush everything still queued (deadline sheds still apply),
//! and the final [`ServeReport`] freezes one [`PerfReport`] per model
//! plus the rolled-up totals.

use super::protocol::{json_str, parse_client_msg, ClientMsg, ServeResponse};
use super::queue::{PushError, ServeRequest};
use super::registry::{ModelDrain, ModelRegistry};
use super::{ServeConfig, ServeStats};
use crate::bnn::Model;
use crate::coordinator::PerfReport;
use crate::Result;
use anyhow::Context;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Process-wide drain flag set by the CLI's SIGTERM/SIGINT handlers (a
/// signal means the whole process is going down, so *every* server in the
/// process honors it). Programmatic drains — the wire `{"op": "drain"}` or
/// [`ServeHandle::drain`] — use a per-server flag instead, so concurrent
/// servers (e.g. parallel tests) never drain each other.
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Request a process-wide graceful drain (what the signal handlers call).
pub fn request_drain() {
    SIGNAL_DRAIN.store(true, Ordering::SeqCst);
}

/// Whether a process-wide signal drain is in flight (the telemetry loop
/// polls this alongside its server's own drain flag).
pub(crate) fn signal_drain_requested() -> bool {
    SIGNAL_DRAIN.load(Ordering::SeqCst)
}

/// A running server: background accept loop plus one batcher per loaded
/// model, and everything needed to account for and report on them at
/// drain time.
pub struct ServeHandle {
    addr: SocketAddr,
    models: Arc<ModelRegistry>,
    draining: Arc<AtomicBool>,
    accept: JoinHandle<()>,
    telemetry: Option<super::telemetry::TelemetryHandle>,
    started: Instant,
}

impl ServeHandle {
    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The telemetry endpoint's bound address, if
    /// [`ServeConfig::metrics_addr`](super::ServeConfig) was set (useful
    /// with port 0).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.telemetry.as_ref().map(|t| t.local_addr())
    }

    /// The server's model registry (route lookups, hot load/unload,
    /// per-model stats).
    pub fn models(&self) -> &Arc<ModelRegistry> {
        &self.models
    }

    /// Whether a drain has been requested (by signal, wire, or handle).
    pub fn drain_requested(&self) -> bool {
        SIGNAL_DRAIN.load(Ordering::SeqCst) || self.draining.load(Ordering::SeqCst)
    }

    /// Block until a drain is requested, polling the flags.
    pub fn wait_for_drain(&self) {
        while !self.drain_requested() {
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Gracefully drain: stop accepting, flush every lane's queue through
    /// its batcher (deadline sheds still apply), join the background
    /// threads, and freeze the final per-model report. The returned
    /// [`ServeReport`] carries one [`PerfReport`] per model — including
    /// models unloaded earlier over the wire — and `admitted == completed
    /// + shed + failed` holds per model and in total, every admitted
    /// request answered.
    pub fn drain(self) -> Result<ServeReport> {
        self.draining.store(true, Ordering::SeqCst);
        self.accept.join().map_err(|_| anyhow::anyhow!("accept loop panicked"))?;
        if let Some(telemetry) = self.telemetry {
            telemetry.join(); // exits on the shared drain flag
        }
        let models = self.models.drain_all();
        let mut total = ServeStats::default();
        for d in &models {
            total.merge(&d.stats);
        }
        Ok(ServeReport { models, total, uptime_ms: self.started.elapsed().as_secs_f64() * 1e3 })
    }
}

/// The final artifact of a drained server: per-model drain receipts plus
/// the server-wide accounting rollup.
#[derive(Debug)]
pub struct ServeReport {
    /// One receipt per model the server ever loaded (wire-unloaded lanes
    /// included), each carrying its own [`PerfReport`].
    pub models: Vec<ModelDrain>,
    /// All lanes' [`ServeStats`] merged.
    pub total: ServeStats,
    /// Server uptime, milliseconds.
    pub uptime_ms: f64,
}

impl ServeReport {
    /// The drain invariant, checked per model *and* on the rollup.
    pub fn accounted(&self) -> bool {
        self.total.accounted() && self.models.iter().all(|m| m.stats.accounted())
    }

    /// The report for one model by registry name.
    pub fn model(&self, name: &str) -> Option<&PerfReport> {
        self.models.iter().find(|m| m.name == name).map(|m| &m.report)
    }

    /// Serialize as `tulip.serve_report/v1`: the rolled-up `serve` block
    /// plus one embedded `tulip.perf_report/v1` per model.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str("  \"schema\": \"tulip.serve_report/v1\",\n");
        s.push_str(&format!("  \"uptime_ms\": {:.3},\n", self.uptime_ms));
        s.push_str(&format!("  \"accounted\": {},\n", self.accounted()));
        s.push_str(&format!("  \"serve\": {{{}}},\n", self.total.json_fields()));
        s.push_str("  \"models\": [");
        for (i, m) in self.models.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": {}, \"serve\": {{{}}}, \"report\": {}}}",
                json_str(&m.name),
                m.stats.json_fields(),
                m.report.to_json()
            ));
        }
        s.push_str("\n  ]\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json())
            .map_err(|e| anyhow::anyhow!("writing serve report {}: {e}", path.as_ref().display()))
    }

    /// Pretty-print: one accounting line per model, then each model's
    /// engine summary.
    pub fn print_summary(&self) {
        println!("serve report — uptime {:.1} ms, accounted: {}", self.uptime_ms, self.accounted());
        let t = &self.total;
        println!(
            "total: admitted {} = completed {} + shed {} + failed {} (rejected {})",
            t.admitted, t.completed, t.shed, t.failed, t.rejected
        );
        for m in &self.models {
            let s = &m.stats;
            println!(
                "\nmodel '{}': admitted {} = completed {} + shed {} + failed {} (rejected {})",
                m.name, s.admitted, s.completed, s.shed, s.failed, s.rejected
            );
            m.report.print_summary();
        }
    }
}

/// Load `models` (name → [`Model`], the first being the default route),
/// bind and start serving. Returns as soon as the listener is bound; use
/// the returned handle to wait and drain.
pub fn serve(models: Vec<(String, Model)>, cfg: ServeConfig) -> Result<ServeHandle> {
    anyhow::ensure!(!models.is_empty(), "serve needs at least one model");
    let registry = Arc::new(ModelRegistry::new(cfg.clone()));
    for (name, model) in models {
        registry.load(&name, model).with_context(|| format!("loading model '{name}'"))?;
    }
    let draining = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let addr = listener.local_addr().context("local addr")?;

    let telemetry = match &cfg.metrics_addr {
        Some(maddr) => Some(
            super::telemetry::start(maddr, Arc::clone(&registry), Arc::clone(&draining))
                .with_context(|| format!("starting telemetry on {maddr}"))?,
        ),
        None => None,
    };

    let accept = {
        let registry = Arc::clone(&registry);
        let draining = Arc::clone(&draining);
        std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, registry, draining))
            .context("spawning accept loop")?
    };

    let started = Instant::now();
    Ok(ServeHandle { addr, models: registry, draining, accept, telemetry, started })
}

/// Poll-accept until a drain is requested (nonblocking listener + short
/// sleep, so the loop notices the flags without a connection arriving).
fn accept_loop(listener: TcpListener, registry: Arc<ModelRegistry>, draining: Arc<AtomicBool>) {
    while !SIGNAL_DRAIN.load(Ordering::SeqCst) && !draining.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let registry = Arc::clone(&registry);
                let draining = Arc::clone(&draining);
                let _ = std::thread::Builder::new().name("serve-conn".into()).spawn(move || {
                    let _ = handle_connection(stream, &registry, &draining);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Spawn the writer thread for one connection: drains encoded response
/// lines from `rx` into the socket. Exits when every `Sender` clone is
/// gone (reader done *and* no request of this connection still queued).
fn spawn_writer(stream: TcpStream, rx: Receiver<String>) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("serve-writer".into())
        .spawn(move || {
            let mut w = BufWriter::new(stream);
            while let Ok(line) = rx.recv() {
                if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                    break; // client gone; replies are best-effort
                }
                let _ = w.flush();
            }
        })
        .expect("spawning connection writer")
}

/// One connection's reader: parse request lines, route them to model
/// lanes, admit them, reply directly on protocol/routing/admission errors
/// and control ops.
fn handle_connection(
    stream: TcpStream,
    registry: &ModelRegistry,
    draining: &AtomicBool,
) -> Result<()> {
    let write_stream = stream.try_clone().context("cloning stream for writer")?;
    let (tx, rx): (Sender<String>, Receiver<String>) = channel();
    let writer = spawn_writer(write_stream, rx);
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // connection reset
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_client_msg(&line) {
            Ok(ClientMsg::Infer(req)) => {
                let lane = match registry.get(req.model.as_deref()) {
                    Ok(lane) => lane,
                    Err(e) => {
                        let msg = e.to_string();
                        let _ = tx.send(ServeResponse::error(req.id, &msg).to_json_line());
                        continue;
                    }
                };
                let image = match req.decode(lane.model().input_dims()) {
                    Ok(image) => image,
                    Err(e) => {
                        let msg = e.to_string();
                        let _ = tx.send(ServeResponse::error(e.request_id(), &msg).to_json_line());
                        continue;
                    }
                };
                let deadline = req.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
                let sreq = ServeRequest {
                    id: req.id,
                    flight: 0, // assigned at admission by the queue
                    image,
                    deadline,
                    enqueued: Instant::now(),
                    resp: tx.clone(),
                };
                match lane.queue().push(sreq) {
                    Ok(()) => {}
                    Err(PushError::Full(r)) => {
                        let _ = tx.send(ServeResponse::rejected(r.id, "queue full").to_json_line());
                    }
                    Err(PushError::Closed(r)) => {
                        let line = ServeResponse::rejected(r.id, "server draining").to_json_line();
                        let _ = tx.send(line);
                    }
                }
            }
            Ok(ClientMsg::Stats) => {
                let _ = tx.send(registry.stats_line());
            }
            Ok(ClientMsg::TraceDump) => {
                let dump = crate::metrics::flight::recorder().snapshot();
                let _ = tx.send(dump.to_json_line());
            }
            Ok(ClientMsg::Drain) => {
                let _ = tx.send("{\"op\": \"drain\", \"ack\": true}".to_string());
                draining.store(true, Ordering::SeqCst);
                break;
            }
            Ok(ClientMsg::LoadModel { name, doc }) => {
                let loaded =
                    Model::from_json_value(&doc).and_then(|model| registry.load(&name, model));
                let reply = match loaded {
                    Ok(()) => format!(
                        "{{\"op\": \"load_model\", \"name\": {}, \"ok\": true}}",
                        json_str(&name)
                    ),
                    Err(e) => format!(
                        "{{\"op\": \"load_model\", \"name\": {}, \"ok\": false, \"error\": {}}}",
                        json_str(&name),
                        json_str(&e.to_string())
                    ),
                };
                let _ = tx.send(reply);
            }
            Ok(ClientMsg::UnloadModel { name }) => {
                let reply = match registry.unload(&name) {
                    Ok(stats) => format!(
                        "{{\"op\": \"unload_model\", \"name\": {}, \"ok\": true, \
                         \"accounted\": {}, {}}}",
                        json_str(&name),
                        stats.accounted(),
                        stats.json_fields()
                    ),
                    Err(e) => format!(
                        "{{\"op\": \"unload_model\", \"name\": {}, \"ok\": false, \"error\": {}}}",
                        json_str(&name),
                        json_str(&e.to_string())
                    ),
                };
                let _ = tx.send(reply);
            }
            Err(e) => {
                let msg = e.to_string();
                let _ = tx.send(ServeResponse::error(e.request_id(), &msg).to_json_line());
            }
        }
    }
    // Drop our sender; the writer exits once queued requests (which hold
    // clones) have been answered and released by the batcher.
    drop(tx);
    let _ = writer.join();
    Ok(())
}

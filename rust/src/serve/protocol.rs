//! `tulip.serve/v1` — the std-only JSON-lines wire protocol.
//!
//! One request or response per line. The vendored dependency set has no
//! serde, so this module carries a minimal hand-rolled JSON parser (the
//! mirror of the hand-rolled encoder in `coordinator::perf_report`) plus
//! the typed request/response shapes and the packed-bits codec.
//!
//! Request (`{"op": …}` lines are control messages instead):
//!
//! ```json
//! {"id": 7, "model": "tiny", "bits": "a3f0…", "h": 16, "w": 16, "c": 8, "deadline_ms": 50}
//! ```
//!
//! * `id` — client-chosen, echoed on the response;
//! * `model` — optional model name; omitted requests go to the server's
//!   default (first-loaded) model;
//! * `bits` — the HWC activation bits, packed LSB-first into bytes and
//!   hex-encoded (see [`pack_bits`]);
//! * `h`/`w`/`c` — optional declared shape, validated against the routed
//!   model at decode time;
//! * `deadline_ms` — optional: if the request is still queued this many
//!   milliseconds after receipt it is **shed** (never executed), and the
//!   response carries `"status": "shed"`.
//!
//! Control ops: `{"op": "stats"}`, `{"op": "drain"}`,
//! `{"op": "load_model", "name": "…", "model": { tulip.model/v1 doc }}`,
//! `{"op": "unload_model", "name": "…"}` (see `serve::registry`) and
//! `{"op": "trace_dump"}` (the flight recorder as one `tulip.trace/v1`
//! line, see `metrics::flight`).
//!
//! Response: `{"id": 7, "status": "ok", "class": 2, "scores": [...],
//! "batch_n": 64, "lat_us": {"queue": …, "batch": …, "total": …}}`, or
//! `status` ∈ `shed` / `rejected` (429-style admission failure) / `error`
//! with an `"error"` message.

use crate::bnn::tensor::BitTensor;
use crate::error::Error;
use anyhow::{bail, ensure, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, held as `f64`.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, fields in source order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match *self {
            Json::Num(v) if v >= 0.0 && v.fract() == 0.0 && v <= u64::MAX as f64 => Some(v as u64),
            _ => None,
        }
    }

    /// The value as a signed integer, if it is one exactly.
    pub fn as_i64(&self) -> Option<i64> {
        match *self {
            Json::Num(v) if v.fract() == 0.0 && v.abs() <= i64::MAX as f64 => Some(v as i64),
            _ => None,
        }
    }

    /// The value as a string slice, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }
}

/// Parse one JSON document (one request or response line).
pub fn parse_json(s: &str) -> Result<Json> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    ensure!(p.i == p.b.len(), "trailing bytes after JSON document at offset {}", p.i);
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        ensure!(
            self.peek() == Some(c),
            "expected '{}' at offset {}, found {:?}",
            c as char,
            self.i,
            self.peek().map(|b| b as char)
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "malformed literal at offset {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected byte '{}' at offset {}", c as char, self.i),
            None => bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            fields.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pair: a high surrogate must be
                            // followed by an escaped low surrogate.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                ensure!(
                                    self.b[self.i + 1..].starts_with(br"\u"),
                                    "lone high surrogate at offset {}",
                                    self.i
                                );
                                self.i += 2;
                                let lo = self.hex4()?;
                                ensure!((0xDC00..0xE000).contains(&lo), "invalid low surrogate");
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| anyhow::anyhow!("invalid codepoint {c:#x}"))?,
                            );
                        }
                        _ => bail!("invalid escape at offset {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (the input is a &str, so
                    // boundaries are valid by construction).
                    let rest = std::str::from_utf8(&self.b[self.i..]).expect("input was a str");
                    let c = rest.chars().next().expect("peeked non-empty");
                    ensure!((c as u32) >= 0x20, "unescaped control character in string");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits after `\u`, cursor left on the last digit.
    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            self.i += 1;
            let d = match self.peek() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => bail!("invalid \\u escape at offset {}", self.i),
            };
            v = v << 4 | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii digits");
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => bail!("malformed number '{text}' at offset {start}"),
        }
    }
}

/// JSON string literal with escaping (the encoder half of the protocol).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Pack HWC-ordered activation bits for the wire: bit `k` of the tensor is
/// bit `k % 8` (LSB first) of byte `k / 8`; bytes are lowercase hex.
pub fn pack_bits(bits: &[bool]) -> String {
    let mut out = String::with_capacity(bits.len().div_ceil(8) * 2);
    for chunk in bits.chunks(8) {
        let mut byte = 0u8;
        for (i, &b) in chunk.iter().enumerate() {
            byte |= (b as u8) << i;
        }
        out.push_str(&format!("{byte:02x}"));
    }
    out
}

/// Decode exactly `n` activation bits from a hex payload (inverse of
/// [`pack_bits`]; spare high bits of the last byte are ignored).
pub fn unpack_bits(hex: &str, n: usize) -> Result<Vec<bool>> {
    let bytes = n.div_ceil(8);
    ensure!(
        hex.len() == bytes * 2,
        "bits payload is {} hex chars, expected {} for {} bits",
        hex.len(),
        bytes * 2,
        n
    );
    let nibble = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => bail!("invalid hex byte '{}'", c as char),
        }
    };
    let hb = hex.as_bytes();
    let mut decoded = Vec::with_capacity(bytes);
    for k in 0..bytes {
        decoded.push(nibble(hb[2 * k])? << 4 | nibble(hb[2 * k + 1])?);
    }
    Ok((0..n).map(|k| decoded[k / 8] >> (k % 8) & 1 != 0).collect())
}

/// A decoded client line.
#[derive(Debug, Clone, PartialEq)]
pub enum ClientMsg {
    /// One single-image inference request.
    Infer(InferRequest),
    /// `{"op": "stats"}` — snapshot the server's serve counters.
    Stats,
    /// `{"op": "drain"}` — graceful shutdown: stop accepting, flush the
    /// queue, emit the final perf report and exit.
    Drain,
    /// `{"op": "load_model", "name": …, "model": …}` — hot-load a
    /// `tulip.model/v1` document under the given name.
    LoadModel {
        /// Registry name for the new model.
        name: String,
        /// The inline `tulip.model/v1` document, not yet decoded.
        doc: Json,
    },
    /// `{"op": "unload_model", "name": …}` — drain and retire one model.
    UnloadModel {
        /// Registry name of the model to retire.
        name: String,
    },
    /// `{"op": "trace_dump"}` — dump the flight recorder as one
    /// `tulip.trace/v1` JSON line.
    TraceDump,
}

/// A single-image inference request (see the [module docs](self) for the
/// wire form). The payload stays hex-encoded until the server has routed
/// the request to a model and knows which input geometry to decode
/// against — see [`InferRequest::decode`].
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    /// Client-chosen request id, echoed on the response.
    pub id: u64,
    /// Target model name (`None` routes to the server's default model).
    pub model: Option<String>,
    /// The still-packed activation bits, lowercase hex.
    pub bits_hex: String,
    /// Declared shape `[h, w, c]`, each field optional on the wire.
    pub declared: [Option<u64>; 3],
    /// Optional deadline in milliseconds from receipt.
    pub deadline_ms: Option<u64>,
}

impl InferRequest {
    /// Decode the payload against the routed model's input geometry:
    /// declared `h`/`w`/`c` fields, when present, must match, and the
    /// `bits` payload must carry exactly `h·w·c` bits.
    pub fn decode(
        &self,
        (h, w, c): (usize, usize, usize),
    ) -> std::result::Result<BitTensor, Error> {
        for ((key, expect), got) in [("h", h), ("w", w), ("c", c)].into_iter().zip(self.declared) {
            if let Some(g) = got {
                if g != expect as u64 {
                    return Err(Error::Protocol {
                        id: self.id,
                        msg: format!("shape mismatch: request {key}={g}, model expects {expect}"),
                    });
                }
            }
        }
        let bits = unpack_bits(&self.bits_hex, h * w * c)
            .map_err(|e| Error::Protocol { id: self.id, msg: format!("{e:#}") })?;
        Ok(BitTensor { h, w, c, data: bits })
    }
}

/// Parse one client line into a typed message. Inference payloads are
/// *not* decoded here — shape validation happens in
/// [`InferRequest::decode`] once the server knows which model the request
/// routes to.
pub fn parse_client_msg(line: &str) -> std::result::Result<ClientMsg, Error> {
    let fail = |id: u64, msg: String| Error::Protocol { id, msg };
    let v = parse_json(line).map_err(|e| fail(0, format!("{e:#}")))?;
    if let Some(op) = v.get("op").and_then(Json::as_str) {
        let name = |v: &Json| -> std::result::Result<String, Error> {
            v.get("name")
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| fail(0, format!("op '{op}' requires a string 'name'")))
        };
        return match op {
            "stats" => Ok(ClientMsg::Stats),
            "drain" => Ok(ClientMsg::Drain),
            "load_model" => {
                let name = name(&v)?;
                let doc = v
                    .get("model")
                    .cloned()
                    .ok_or_else(|| fail(0, "op 'load_model' requires a 'model' document".into()))?;
                Ok(ClientMsg::LoadModel { name, doc })
            }
            "unload_model" => Ok(ClientMsg::UnloadModel { name: name(&v)? }),
            "trace_dump" => Ok(ClientMsg::TraceDump),
            other => Err(fail(
                0,
                format!("unknown op '{other}' (stats|drain|load_model|unload_model|trace_dump)"),
            )),
        };
    }
    let id =
        v.get("id").and_then(Json::as_u64).ok_or_else(|| fail(0, "missing numeric 'id'".into()))?;
    let model = v.get("model").and_then(Json::as_str).map(str::to_string);
    let bits_hex = v
        .get("bits")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| fail(id, "missing string 'bits'".into()))?;
    let mut declared = [None; 3];
    for (slot, key) in declared.iter_mut().zip(["h", "w", "c"]) {
        if let Some(d) = v.get(key) {
            *slot = Some(
                d.as_u64()
                    .ok_or_else(|| fail(id, format!("'{key}' must be a non-negative integer")))?,
            );
        }
    }
    let deadline_ms = match v.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(d) => Some(
            d.as_u64()
                .ok_or_else(|| fail(id, "'deadline_ms' must be a non-negative integer".into()))?,
        ),
    };
    Ok(ClientMsg::Infer(InferRequest { id, model, bits_hex, declared, deadline_ms }))
}

/// Response status over the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Classified; `class`/`scores`/`lat_us` are present.
    Ok,
    /// Deadline expired while queued — shed before execution.
    Shed,
    /// Refused at admission (queue full under `Reject`, or draining) —
    /// the 429 of this protocol.
    Rejected,
    /// Malformed request or internal execution failure.
    Error,
}

impl Status {
    /// Wire name.
    pub fn name(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Shed => "shed",
            Status::Rejected => "rejected",
            Status::Error => "error",
        }
    }

    /// Parse a wire name.
    pub fn from_name(s: &str) -> Option<Status> {
        match s {
            "ok" => Some(Status::Ok),
            "shed" => Some(Status::Shed),
            "rejected" => Some(Status::Rejected),
            "error" => Some(Status::Error),
            _ => None,
        }
    }
}

/// One response line (the server's half of `tulip.serve/v1`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    /// Echo of the request id.
    pub id: u64,
    /// Outcome.
    pub status: Status,
    /// Predicted class (`ok` only).
    pub class: Option<usize>,
    /// Raw final-layer scores (`ok` only).
    pub scores: Vec<i64>,
    /// Occupancy of the micro-batch this request ran in (`ok` only).
    pub batch_n: usize,
    /// Time spent queued before dequeue, µs (`ok` only).
    pub queue_us: u64,
    /// Execution wall time of the micro-batch, µs (`ok` only).
    pub batch_us: u64,
    /// Receipt-to-response time, µs (`ok` only).
    pub total_us: u64,
    /// Failure cause (`shed`/`rejected`/`error`).
    pub error: Option<String>,
}

impl ServeResponse {
    fn base(id: u64, status: Status) -> Self {
        ServeResponse {
            id,
            status,
            class: None,
            scores: Vec::new(),
            batch_n: 0,
            queue_us: 0,
            batch_us: 0,
            total_us: 0,
            error: None,
        }
    }

    /// A successful classification.
    #[allow(clippy::too_many_arguments)]
    pub fn ok(
        id: u64,
        class: usize,
        scores: Vec<i64>,
        batch_n: usize,
        queue_us: u64,
        batch_us: u64,
        total_us: u64,
    ) -> Self {
        ServeResponse {
            class: Some(class),
            scores,
            batch_n,
            queue_us,
            batch_us,
            total_us,
            ..Self::base(id, Status::Ok)
        }
    }

    /// A deadline shed (counted, never executed).
    pub fn shed(id: u64) -> Self {
        ServeResponse {
            error: Some("deadline expired before execution".into()),
            ..Self::base(id, Status::Shed)
        }
    }

    /// An admission rejection (queue full / draining).
    pub fn rejected(id: u64, why: &str) -> Self {
        ServeResponse { error: Some(why.to_string()), ..Self::base(id, Status::Rejected) }
    }

    /// A request-level error.
    pub fn error(id: u64, why: &str) -> Self {
        ServeResponse { error: Some(why.to_string()), ..Self::base(id, Status::Error) }
    }

    /// Encode as one JSON line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        let mut s = format!("{{\"id\": {}, \"status\": {}", self.id, json_str(self.status.name()));
        if let Some(class) = self.class {
            let scores: Vec<String> = self.scores.iter().map(|v| v.to_string()).collect();
            s.push_str(&format!(
                ", \"class\": {class}, \"scores\": [{}], \"batch_n\": {}, \
                 \"lat_us\": {{\"queue\": {}, \"batch\": {}, \"total\": {}}}",
                scores.join(", "),
                self.batch_n,
                self.queue_us,
                self.batch_us,
                self.total_us
            ));
        }
        if let Some(e) = &self.error {
            s.push_str(&format!(", \"error\": {}", json_str(e)));
        }
        s.push('}');
        s
    }

    /// Decode one response line (used by clients and tests).
    pub fn parse(line: &str) -> Result<ServeResponse> {
        let v = parse_json(line)?;
        let id = v.get("id").and_then(Json::as_u64).unwrap_or(0);
        let status = v
            .get("status")
            .and_then(Json::as_str)
            .and_then(Status::from_name)
            .ok_or_else(|| anyhow::anyhow!("missing/unknown 'status' in response"))?;
        let mut resp = Self::base(id, status);
        resp.class = v.get("class").and_then(Json::as_u64).map(|c| c as usize);
        if let Some(Json::Arr(items)) = v.get("scores") {
            resp.scores = items.iter().filter_map(Json::as_i64).collect();
            ensure!(resp.scores.len() == items.len(), "non-integer score in response");
        }
        resp.batch_n = v.get("batch_n").and_then(Json::as_u64).unwrap_or(0) as usize;
        if let Some(lat) = v.get("lat_us") {
            resp.queue_us = lat.get("queue").and_then(Json::as_u64).unwrap_or(0);
            resp.batch_us = lat.get("batch").and_then(Json::as_u64).unwrap_or(0);
            resp.total_us = lat.get("total").and_then(Json::as_u64).unwrap_or(0);
        }
        resp.error = v.get("error").and_then(Json::as_str).map(str::to_string);
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip_basics() {
        let v = parse_json(r#"{"a": [1, -2.5, true, null], "b": "x\"\\\nAé"}"#).unwrap();
        assert_eq!(v.get("a").unwrap(), &Json::Arr(vec![
            Json::Num(1.0),
            Json::Num(-2.5),
            Json::Bool(true),
            Json::Null
        ]));
        assert_eq!(v.get("b").and_then(Json::as_str), Some("x\"\\\nAé"));
        assert!(parse_json("{\"a\": 1,}").is_err(), "trailing comma rejected");
        assert!(parse_json("{} extra").is_err(), "trailing bytes rejected");
        assert!(parse_json("[1, 1e999]").is_err(), "non-finite number rejected");
    }

    #[test]
    fn surrogate_pairs_decode() {
        let v = parse_json(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
        assert!(parse_json(r#""\ud83d""#).is_err(), "lone surrogate rejected");
    }

    #[test]
    fn bits_pack_unpack_round_trip() {
        for n in [0usize, 1, 7, 8, 9, 63, 64, 100] {
            let bits: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            let hex = pack_bits(&bits);
            assert_eq!(hex.len(), n.div_ceil(8) * 2);
            assert_eq!(unpack_bits(&hex, n).unwrap(), bits, "n = {n}");
        }
        assert!(unpack_bits("zz", 8).is_err());
        assert!(unpack_bits("00", 16).is_err(), "length must match");
    }

    #[test]
    fn request_parse_and_decode_validate_shape_and_bits() {
        let input = (2, 2, 2); // 8 bits = 1 byte
        let ok = parse_client_msg(r#"{"id": 3, "bits": "a5", "deadline_ms": 10}"#).unwrap();
        match ok {
            ClientMsg::Infer(r) => {
                assert_eq!(r.id, 3);
                assert_eq!(r.model, None);
                assert_eq!(r.deadline_ms, Some(10));
                let img = r.decode(input).unwrap();
                assert_eq!(img.data, unpack_bits("a5", 8).unwrap());
            }
            other => panic!("expected Infer, got {other:?}"),
        }
        // The model field routes; declared shape must match at decode time.
        let m = parse_client_msg(r#"{"id": 4, "model": "tiny", "h": 3, "bits": "a5"}"#).unwrap();
        match m {
            ClientMsg::Infer(r) => {
                assert_eq!(r.model.as_deref(), Some("tiny"));
                let e = r.decode(input).unwrap_err();
                assert_eq!(e.request_id(), 4);
                assert!(e.to_string().contains("shape mismatch"), "{e}");
            }
            other => panic!("expected Infer, got {other:?}"),
        }
        // Wrong payload length fails at decode, blamed on the request id.
        match parse_client_msg(r#"{"id": 5, "bits": "a5ff"}"#).unwrap() {
            ClientMsg::Infer(r) => assert_eq!(r.decode(input).unwrap_err().request_id(), 5),
            other => panic!("expected Infer, got {other:?}"),
        }
        // Control messages.
        assert_eq!(parse_client_msg(r#"{"op": "stats"}"#).unwrap(), ClientMsg::Stats);
        assert_eq!(parse_client_msg(r#"{"op": "drain"}"#).unwrap(), ClientMsg::Drain);
        match parse_client_msg(r#"{"op": "load_model", "name": "z", "model": {}}"#).unwrap() {
            ClientMsg::LoadModel { name, doc } => {
                assert_eq!(name, "z");
                assert_eq!(doc, Json::Obj(vec![]));
            }
            other => panic!("expected LoadModel, got {other:?}"),
        }
        assert_eq!(
            parse_client_msg(r#"{"op": "unload_model", "name": "z"}"#).unwrap(),
            ClientMsg::UnloadModel { name: "z".into() }
        );
        assert_eq!(parse_client_msg(r#"{"op": "trace_dump"}"#).unwrap(), ClientMsg::TraceDump);
        assert!(parse_client_msg(r#"{"op": "load_model"}"#).is_err(), "name required");
        assert!(parse_client_msg(r#"{"op": "reboot"}"#).is_err());
    }

    #[test]
    fn response_encode_decode_round_trip() {
        let ok = ServeResponse::ok(9, 2, vec![-4, 7, 12], 64, 120, 900, 1100);
        let back = ServeResponse::parse(&ok.to_json_line()).unwrap();
        assert_eq!(back, ok);
        let shed = ServeResponse::shed(5);
        let back = ServeResponse::parse(&shed.to_json_line()).unwrap();
        assert_eq!(back.status, Status::Shed);
        assert!(back.error.unwrap().contains("deadline"));
        let rej = ServeResponse::rejected(1, "queue full");
        assert_eq!(ServeResponse::parse(&rej.to_json_line()).unwrap().status, Status::Rejected);
    }
}

//! Carry-lookahead extension — the paper's footnote 3 (§III):
//!
//! > "This can be changed to implement a two-bit or three-bit
//! > carry-lookahead addition. Doing so would simply require a binary
//! > neuron with a different set of weights, and could increase the
//! > throughput at the expense of a small increase in area and power. We
//! > plan to address this in future work."
//!
//! The enabling identity: the carry out of a `g`-bit group is itself a
//! threshold function of the group's operand bits and the incoming carry —
//! for `g = 2`, `c_out = [2·x1 + 2·y1 + x0 + y0 + c_in ≥ 4]` (weights
//! `[2,2,1,1,1; 4]`), because the weighted sum *is* `x + y + c_in` of the
//! 2-bit group. Generally a `g`-bit group needs weights
//! `[2^{g-1}, 2^{g-1}, …, 1, 1, 1]` and threshold `2^g` — a wider
//! LIN/RIN differential network, hence the paper's "small increase in area
//! and power".
//!
//! We model the extension **analytically** (the evaluated silicon uses the
//! 1-bit cell; this module is the ablation for the design choice DESIGN.md
//! calls out): a `w`-bit ripple addition drops from `w` to `⌈w/g⌉` cycles,
//! leaf cycles are unchanged, and the cell energy/area scale by the fitted
//! per-group factors below.

use super::adder_tree::AdderTree;

/// Adder scheme for the TULIP-PE datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdderScheme {
    /// The evaluated design: full-adder cascade, 1 bit/cycle.
    RippleFa,
    /// Two-bit carry-lookahead cells (`[2,2,1,1,1; T]`).
    Cla2,
    /// Three-bit carry-lookahead cells (`[4,4,2,2,1,1,1; T]`).
    Cla3,
}

impl AdderScheme {
    /// Bits retired per addition cycle.
    pub fn group_bits(self) -> usize {
        match self {
            AdderScheme::RippleFa => 1,
            AdderScheme::Cla2 => 2,
            AdderScheme::Cla3 => 3,
        }
    }

    /// Cell-area factor vs the `[2,1,1,1]` cell. The mixed-signal cell's
    /// area is dominated by the LIN/RIN input networks, which grow with
    /// the total input weight (5 → 7 → 15): fitted linearly in Σw.
    pub fn cell_area_factor(self) -> f64 {
        match self {
            AdderScheme::RippleFa => 1.0,
            AdderScheme::Cla2 => 1.0 + (7.0 - 5.0) / 5.0 * 0.8,   // ≈ 1.32
            AdderScheme::Cla3 => 1.0 + (15.0 - 5.0) / 5.0 * 0.8,  // ≈ 2.6
        }
    }

    /// Per-evaluation energy factor (same Σw argument; dynamic energy of
    /// the differential networks scales with the switched weight).
    pub fn cell_energy_factor(self) -> f64 {
        match self {
            AdderScheme::RippleFa => 1.0,
            AdderScheme::Cla2 => 1.35,
            AdderScheme::Cla3 => 2.1,
        }
    }

    /// Every scheme, in ablation order.
    pub const ALL: [AdderScheme; 3] = [AdderScheme::RippleFa, AdderScheme::Cla2, AdderScheme::Cla3];
}

impl std::fmt::Display for AdderScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdderScheme::RippleFa => write!(f, "ripple-FA"),
            AdderScheme::Cla2 => write!(f, "CLA-2"),
            AdderScheme::Cla3 => write!(f, "CLA-3"),
        }
    }
}

/// Adder-tree summation cycles under a scheme: leaves stay 1 cycle (one
/// full-adder evaluation already retires a 3-input group); each internal
/// `max(w_l, w_r)`-bit addition retires `g` bits/cycle.
pub fn tree_cycles(n: usize, scheme: AdderScheme) -> u64 {
    let tree = AdderTree::build(n);
    let g = scheme.group_bits() as u64;
    tree.nodes
        .iter()
        .map(|nd| match nd.children {
            None => 1,
            Some((l, r)) => {
                let w = tree.nodes[l].width.max(tree.nodes[r].width) as u64;
                w.div_ceil(g)
            }
        })
        .sum()
}

/// Full threshold-node cycles (tree + comparison; the sequential
/// comparator also retires `g` bits/cycle with lookahead cells).
pub fn node_cycles(n: usize, scheme: AdderScheme) -> u64 {
    let root_w = AdderTree::build(n).root_width() as u64;
    tree_cycles(n, scheme) + root_w.div_ceil(scheme.group_bits() as u64)
}

/// Ablation row: cycles, PE-energy factor and PE-area factor for one node.
#[derive(Debug, Clone, Copy)]
pub struct ClaAblation {
    /// The adder scheme this row ablates.
    pub scheme: AdderScheme,
    /// Threshold-node cycles under the scheme.
    pub node_cycles: u64,
    /// Cycle speedup relative to ripple-FA.
    pub speedup_vs_fa: f64,
    /// PE-area factor relative to ripple-FA.
    pub area_factor: f64,
    /// Energy per node relative to ripple-FA: fewer cycles × costlier
    /// evaluations.
    pub energy_factor: f64,
}

/// Compute the ablation for an `n`-input node.
pub fn ablation(n: usize) -> Vec<ClaAblation> {
    let base = node_cycles(n, AdderScheme::RippleFa) as f64;
    AdderScheme::ALL
        .iter()
        .map(|&s| {
            let c = node_cycles(n, s);
            ClaAblation {
                scheme: s,
                node_cycles: c,
                speedup_vs_fa: base / c as f64,
                area_factor: s.cell_area_factor(),
                energy_factor: (c as f64 / base) * s.cell_energy_factor(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::adder_tree::threshold_node;

    /// Ripple-FA cycles from this module equal the real generated schedule
    /// (the analytic formula and the control-word emitter agree).
    #[test]
    fn ripple_matches_generated_schedule() {
        for &n in &[9usize, 48, 288, 1023] {
            let sched = threshold_node(n, (n / 2) as i64);
            assert_eq!(
                node_cycles(n, AdderScheme::RippleFa),
                sched.total_cycles(),
                "n={n}"
            );
        }
    }

    /// CLA-2 roughly halves addition cycles; CLA-3 roughly thirds them
    /// (leaves bound the gain from above).
    #[test]
    fn lookahead_speedups_bounded() {
        for &n in &[288usize, 1023] {
            let rows = ablation(n);
            assert!(rows[1].speedup_vs_fa > 1.4 && rows[1].speedup_vs_fa < 2.0, "{:?}", rows[1]);
            assert!(rows[2].speedup_vs_fa > 1.7 && rows[2].speedup_vs_fa < 3.0, "{:?}", rows[2]);
            // Monotone: more lookahead, fewer cycles.
            assert!(rows[0].node_cycles > rows[1].node_cycles);
            assert!(rows[1].node_cycles > rows[2].node_cycles);
        }
    }

    /// The paper's framing: "increase the throughput at the expense of a
    /// small increase in area and power" — energy per node must not
    /// balloon (CLA-2 stays within ~±10% of FA energy in this model).
    #[test]
    fn cla2_energy_near_parity() {
        let rows = ablation(288);
        assert!(rows[1].energy_factor < 1.1, "{:?}", rows[1]);
        assert!(rows[1].area_factor < 1.5);
    }

    /// The 2-bit group carry identity the whole extension rests on:
    /// c_out = [2x1 + 2y1 + x0 + y0 + cin >= 4], exhaustively.
    #[test]
    fn group_carry_is_threshold_function() {
        for m in 0u32..32 {
            let (x0, y0, x1, y1, cin) =
                (m & 1 != 0, m & 2 != 0, m & 4 != 0, m & 8 != 0, m & 16 != 0);
            let x = (x1 as u32) * 2 + x0 as u32;
            let y = (y1 as u32) * 2 + y0 as u32;
            let carry_out = x + y + cin as u32 >= 4;
            let weighted =
                2 * x1 as u32 + 2 * y1 as u32 + x0 as u32 + y0 as u32 + cin as u32;
            assert_eq!(carry_out, weighted >= 4, "m={m:05b}");
        }
    }
}

//! Primitive TULIP-PE schedules (Fig. 4 and Fig. 5 of the paper).
//!
//! Every BNN operation — addition, accumulation, comparison (and with it
//! batch normalization), max-pooling and ReLU — is generated here as a
//! sequence of control words for the *same* `[2,1,1,1;T]` cell, which is
//! the paper's central claim ("exactly one such cell is needed to implement
//! all necessary primitive functions in a BNN").
//!
//! Cycle-count contracts (used verbatim by the analytic performance model —
//! `sim::perf` asserts they match bit-true execution):
//!
//! | op                        | cycles                    |
//! |---------------------------|---------------------------|
//! | 3-input leaf add          | 1                         |
//! | `w`-bit + `w`-bit add     | `w` (result `w+1` bits)   |
//! | accumulate step           | `max(w_acc, w_x)`         |
//! | `w`-bit compare           | `w`                       |
//! | `n`-input maxpool (OR)    | `1 + ⌈max(0,n−4)/3⌉`      |
//! | `w`-bit ReLU              | `2w`                      |

use super::{ExtSpec, Loc, Schedule};
use crate::pe::{ControlWord, NeuronCtl, RegWrite, Src, WSrc};

/// Default neuron roles, matching Fig. 4(a): N2 computes sums, N3 carries.
pub const SUM_N: usize = 1;
/// See [`SUM_N`].
pub const CARRY_N: usize = 2;
/// Comparator verdict neuron (Fig. 5a uses a single 3-input function).
pub const CMP_N: usize = 0;
/// AND neuron for ReLU's final masking step.
pub const AND_N: usize = 3;

/// Place `spec` on external channel `ch` of a row, padding gaps.
fn set_ext(row: &mut Vec<ExtSpec>, ch: usize, spec: ExtSpec) {
    while row.len() <= ch {
        row.push(ExtSpec::Lit(false));
    }
    row[ch] = spec;
}

/// The bus source for bit `i` of an operand, plus its external demand.
fn bit_src(loc: &Loc, i: usize, row: &mut Vec<ExtSpec>) -> Src {
    if i >= loc.width() {
        return Src::Zero;
    }
    match *loc {
        Loc::Reg { reg, lsb, .. } => Src::Reg { reg, bit: lsb + i },
        Loc::Const { value, .. } => {
            if value >> i & 1 != 0 {
                Src::One
            } else {
                Src::Zero
            }
        }
        Loc::Stream { channel, base, .. } => {
            set_ext(row, channel, ExtSpec::Product(base + i));
            Src::Ext(channel)
        }
    }
}

/// Bit-serial ripple addition (Fig. 4a): `dst[0..w] = x + y`, `w = max
/// widths`, result is `w+1` bits at `(dst_reg, dst_lsb)`.
///
/// Per cycle `i`: the shared buses carry `x_i`/`y_i`; the carry neuron
/// (phase 0) computes `c_i = maj(x_i, y_i, c_{i−1})` through its own output
/// latch; the sum neuron (phase 1) computes
/// `s_i = [2·¬c_i + x_i + y_i + c_{i−1} ≥ 3]` via the neuron cascade. The
/// final cycle writes both `s_{w−1}` and the carry-out.
pub fn add(
    x: Loc,
    y: Loc,
    dst_reg: usize,
    dst_lsb: usize,
    sum_n: usize,
    carry_n: usize,
) -> Schedule {
    assert_ne!(sum_n, carry_n, "sum and carry need distinct neurons");
    if let (Some(rx), Some(ry)) = (x.reg(), y.reg()) {
        assert_ne!(rx, ry, "operands must live in distinct registers (one read port each)");
    }
    for src in [&x, &y] {
        if let Some(r) = src.reg() {
            // dst may share a register with a source only on disjoint bits;
            // the tree allocator never does this, but enforce safety here.
            if r == dst_reg {
                if let Loc::Reg { lsb, width, .. } = *src {
                    let w = x.width().max(y.width());
                    assert!(
                        dst_lsb + w + 1 <= lsb || lsb + width <= dst_lsb,
                        "destination overlaps a source field"
                    );
                }
            }
        }
    }
    let w = x.width().max(y.width());
    assert!(w > 0);
    let mut sched = Schedule::new();
    for i in 0..w {
        let mut row = Vec::new();
        let bx = bit_src(&x, i, &mut row);
        let by = bit_src(&y, i, &mut row);
        let cin = if i == 0 { Src::Zero } else { Src::N(carry_n) };
        let mut cw = ControlWord::idle();
        cw.bus_b = bx;
        cw.bus_c = by;
        cw.neurons[carry_n] = NeuronCtl {
            gated: false,
            phase: 0,
            a: Src::Zero,
            b_en: true,
            b_inv: false,
            c_en: true,
            c_inv: false,
            d: cin,
            threshold: 2,
        };
        cw.neurons[sum_n] = NeuronCtl {
            gated: false,
            phase: 1,
            a: Src::NFreshInv(carry_n),
            b_en: true,
            b_inv: false,
            c_en: true,
            c_inv: false,
            d: cin,
            threshold: 3,
        };
        cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb + i, src: WSrc::N(sum_n) });
        if i == w - 1 {
            cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb + w, src: WSrc::N(carry_n) });
        }
        sched.push(cw.with_note(format!("add bit {i}")), row);
    }
    sched
}

/// Leaf node of the adder tree: sum of up to three 1-bit products in a
/// single cycle (the top inset of Fig. 2b — one full-adder evaluation).
/// Result is 2 bits (or 1 bit for a single product) at `(dst_reg, dst_lsb)`.
pub fn leaf(products: &[usize], dst_reg: usize, dst_lsb: usize) -> Schedule {
    assert!((1..=3).contains(&products.len()));
    let mut sched = Schedule::new();
    let mut row = Vec::new();
    for (ch, &p) in products.iter().enumerate() {
        set_ext(&mut row, ch, ExtSpec::Product(p));
    }
    let mut cw = ControlWord::idle();
    if products.len() == 1 {
        // Pass-through: one product bit straight into the register.
        cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb, src: WSrc::Ext(0) });
        sched.push(cw.with_note("leaf copy"), row);
        return sched;
    }
    cw.bus_b = Src::Ext(0);
    cw.bus_c = Src::Ext(1);
    let third = if products.len() == 3 { Src::Ext(2) } else { Src::Zero };
    cw.neurons[CARRY_N] = NeuronCtl {
        gated: false,
        phase: 0,
        a: Src::Zero,
        b_en: true,
        b_inv: false,
        c_en: true,
        c_inv: false,
        d: third,
        threshold: 2,
    };
    cw.neurons[SUM_N] = NeuronCtl {
        gated: false,
        phase: 1,
        a: Src::NFreshInv(CARRY_N),
        b_en: true,
        b_inv: false,
        c_en: true,
        c_inv: false,
        d: third,
        threshold: 3,
    };
    cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb, src: WSrc::N(SUM_N) });
    cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb + 1, src: WSrc::N(CARRY_N) });
    sched.push(cw.with_note(format!("leaf of {}", products.len())), row);
    sched
}

/// Accumulation step (Fig. 4c): `dst = acc + x`. Identical datapath to
/// [`add`]; the Fig. 4(c) alternation of the accumulator between R2 and R4
/// is a register-allocation policy, applied by the caller (see
/// `coordinator`). The result is `max(w_acc, w_x) + 1` bits.
pub fn accumulate(acc: Loc, x: Loc, dst_reg: usize, dst_lsb: usize) -> Schedule {
    add(acc, x, dst_reg, dst_lsb, SUM_N, CARRY_N)
}

/// Sequential comparator (Fig. 5a): after `w` cycles the verdict neuron
/// holds `x > y`. Bits stream LSB→MSB; per cycle
/// `out_i = [x_i + ¬y_i + out_{i−1} ≥ 2]` — a 3-input threshold function
/// ("the first implementation of a sequential comparator that uses 3-input
/// neurons").
pub fn compare_gt(x: Loc, y: Loc, out_n: usize) -> Schedule {
    if let (Some(rx), Some(ry)) = (x.reg(), y.reg()) {
        assert_ne!(rx, ry, "comparator operands share a register read port");
    }
    let w = x.width().max(y.width());
    assert!(w > 0);
    let mut sched = Schedule::new();
    for i in 0..w {
        let mut row = Vec::new();
        let bx = bit_src(&x, i, &mut row);
        let by = bit_src(&y, i, &mut row);
        let mut cw = ControlWord::idle();
        cw.bus_b = bx;
        cw.bus_c = by;
        cw.neurons[out_n] = NeuronCtl {
            gated: false,
            phase: 0,
            a: Src::Zero,
            b_en: true,
            b_inv: false,
            c_en: true,
            c_inv: true, // ¬y_i
            d: if i == 0 { Src::Zero } else { Src::N(out_n) },
            threshold: 2,
        };
        sched.push(cw.with_note(format!("cmp bit {i}")), row);
    }
    sched
}

/// `x ≥ t` against a compile-time constant — the thresholding of Eq. 1 and
/// the paper's batch normalization ("realized by subtracting the value of
/// the bias from the threshold T", §IV-D). Degenerate thresholds collapse
/// to a single constant-latch cycle.
pub fn ge_const(x: Loc, t: i64, out_n: usize) -> Schedule {
    let w = x.width();
    let max_val = (1i64 << w) - 1;
    let mut sched = Schedule::new();
    if t <= 0 || t > max_val {
        // Unconditionally true (T' ≤ 0) or false (T' > max representable).
        let mut cw = ControlWord::idle();
        cw.neurons[out_n] =
            NeuronCtl { gated: false, threshold: if t <= 0 { 0 } else { 6 }, ..NeuronCtl::idle() };
        sched.push(cw.with_note(format!("const {}", t <= 0)), Vec::new());
        return sched;
    }
    // x ≥ t ⇔ x > t − 1.
    sched.extend(compare_gt(x, Loc::Const { value: (t - 1) as u32, width: w }, out_n));
    sched
}

/// The product stream a maxpool schedule consumes (window bits in order).
type ProductIter<'a> = std::iter::Peekable<std::iter::Copied<std::slice::Iter<'a, usize>>>;

/// Max-pooling (Fig. 5b): in a BNN this is an OR over the pooling window.
/// A single neuron ORs up to four window bits in the first cycle
/// (`[2a + b + c + d ≥ 1]`) and folds three more per subsequent cycle
/// through its own latch.
pub fn maxpool_or(products: &[usize], out_n: usize) -> Schedule {
    assert!(!products.is_empty());
    let mut sched = Schedule::new();
    let mut it = products.iter().copied().peekable();
    let mut first = true;
    while it.peek().is_some() || first {
        let mut row = Vec::new();
        let mut cw = ControlWord::idle();
        let take = |row: &mut Vec<ExtSpec>, ch: usize, it: &mut ProductIter| -> Src {
            match it.next() {
                Some(p) => {
                    set_ext(row, ch, ExtSpec::Product(p));
                    Src::Ext(ch)
                }
                None => Src::Zero,
            }
        };
        let a = take(&mut row, 0, &mut it);
        let b = take(&mut row, 1, &mut it);
        let c = take(&mut row, 2, &mut it);
        let d = if first { take(&mut row, 3, &mut it) } else { Src::N(out_n) };
        cw.bus_b = b;
        cw.bus_c = c;
        cw.neurons[out_n] = NeuronCtl {
            gated: false,
            phase: 0,
            a,
            b_en: !matches!(b, Src::Zero),
            b_inv: false,
            c_en: !matches!(c, Src::Zero),
            c_inv: false,
            d,
            threshold: 1,
        };
        sched.push(cw.with_note("maxpool OR"), row);
        first = false;
        if it.peek().is_none() {
            break;
        }
    }
    sched
}

/// ReLU (§IV-D): compare the register-resident input against `t`, then AND
/// the comparator verdict with each input bit (`[1,1;2]` realized as
/// `b + d ≥ 2`), writing the masked value to `dst`.
pub fn relu(x: Loc, t: i64, dst_reg: usize, dst_lsb: usize) -> Schedule {
    let xr = x.reg().expect("ReLU input must be register-resident");
    assert_ne!(xr, dst_reg, "ReLU in-place not supported (read/write port clash)");
    let w = x.width();
    let mut sched = ge_const(x, t, CMP_N);
    for i in 0..w {
        let mut row = Vec::new();
        let bx = bit_src(&x, i, &mut row);
        let mut cw = ControlWord::idle();
        cw.bus_b = bx;
        cw.neurons[AND_N] = NeuronCtl {
            gated: false,
            phase: 0,
            a: Src::Zero,
            b_en: true,
            b_inv: false,
            c_en: false,
            c_inv: false,
            d: Src::N(CMP_N),
            threshold: 2,
        };
        cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb + i, src: WSrc::N(AND_N) });
        sched.push(cw.with_note(format!("relu AND bit {i}")), row);
    }
    sched
}

/// Stream a `w`-bit operand from an input channel into a register, one bit
/// per cycle (operand loading from the image/kernel buffers).
pub fn load_stream(
    channel: usize,
    base: usize,
    w: usize,
    dst_reg: usize,
    dst_lsb: usize,
) -> Schedule {
    let mut sched = Schedule::new();
    for i in 0..w {
        let mut row = Vec::new();
        set_ext(&mut row, channel, ExtSpec::Product(base + i));
        let mut cw = ControlWord::idle();
        cw.writes.push(RegWrite { reg: dst_reg, bit: dst_lsb + i, src: WSrc::Ext(channel) });
        sched.push(cw.with_note(format!("load bit {i}")), row);
    }
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::TulipPe;

    fn bits_of(v: u32, w: usize) -> Vec<bool> {
        (0..w).map(|i| v >> i & 1 != 0).collect()
    }

    /// add(): exhaustive over all 4-bit operand pairs.
    #[test]
    fn add_exhaustive_4bit() {
        for xv in 0u32..16 {
            for yv in 0u32..16 {
                let mut pe = TulipPe::new();
                pe.regs_mut().poke_field(0, 0, 4, xv);
                pe.regs_mut().poke_field(3, 0, 4, yv);
                let s = add(
                    Loc::Reg { reg: 0, lsb: 0, width: 4 },
                    Loc::Reg { reg: 3, lsb: 0, width: 4 },
                    1,
                    0,
                    SUM_N,
                    CARRY_N,
                );
                assert_eq!(s.cycles(), 4);
                assert!(s.validate().is_ok());
                s.run_on(&mut pe, &[]);
                assert_eq!(pe.regs().peek_field(1, 0, 5), xv + yv, "{xv}+{yv}");
            }
        }
    }

    /// Mixed widths: 6-bit + 3-bit.
    #[test]
    fn add_mixed_widths() {
        let mut pe = TulipPe::new();
        pe.regs_mut().poke_field(0, 2, 6, 55);
        pe.regs_mut().poke_field(2, 0, 3, 7);
        let s = add(
            Loc::Reg { reg: 0, lsb: 2, width: 6 },
            Loc::Reg { reg: 2, lsb: 0, width: 3 },
            1,
            4,
            SUM_N,
            CARRY_N,
        );
        assert_eq!(s.cycles(), 6);
        s.run_on(&mut pe, &[]);
        assert_eq!(pe.regs().peek_field(1, 4, 7), 62);
    }

    /// Streamed operands (products) work through the ext map.
    #[test]
    fn add_from_stream() {
        let mut pe = TulipPe::new();
        let s = add(
            Loc::Stream { channel: 0, base: 0, width: 4 },
            Loc::Stream { channel: 1, base: 4, width: 4 },
            2,
            0,
            SUM_N,
            CARRY_N,
        );
        let mut prod = bits_of(9, 4);
        prod.extend(bits_of(13, 4));
        s.run_on(&mut pe, &prod);
        assert_eq!(pe.regs().peek_field(2, 0, 5), 22);
    }

    #[test]
    fn leaf_sums_three_products() {
        for m in 0u32..8 {
            let mut pe = TulipPe::new();
            let s = leaf(&[0, 1, 2], 1, 0);
            assert_eq!(s.cycles(), 1);
            s.run_on(&mut pe, &bits_of(m, 3));
            assert_eq!(pe.regs().peek_field(1, 0, 2), m.count_ones(), "m={m:03b}");
        }
    }

    #[test]
    fn leaf_of_two_and_one() {
        for m in 0u32..4 {
            let mut pe = TulipPe::new();
            leaf(&[0, 1], 0, 3).run_on(&mut pe, &bits_of(m, 2));
            assert_eq!(pe.regs().peek_field(0, 3, 2), m.count_ones());
        }
        let mut pe = TulipPe::new();
        leaf(&[0], 2, 5).run_on(&mut pe, &[true]);
        assert_eq!(pe.regs().peek_field(2, 5, 1), 1);
    }

    /// compare_gt: exhaustive over all 4-bit pairs.
    #[test]
    fn compare_exhaustive_4bit() {
        for xv in 0u32..16 {
            for yv in 0u32..16 {
                let mut pe = TulipPe::new();
                pe.regs_mut().poke_field(0, 0, 4, xv);
                pe.regs_mut().poke_field(1, 0, 4, yv);
                let s = compare_gt(
                    Loc::Reg { reg: 0, lsb: 0, width: 4 },
                    Loc::Reg { reg: 1, lsb: 0, width: 4 },
                    CMP_N,
                );
                assert_eq!(s.cycles(), 4);
                s.run_on(&mut pe, &[]);
                assert_eq!(pe.neuron_out(CMP_N), xv > yv, "{xv} > {yv}");
            }
        }
    }

    /// ge_const covers the batch-norm thresholding path, incl. degenerate T.
    #[test]
    fn ge_const_thresholds() {
        for t in [-3i64, 0, 1, 7, 15, 16, 99] {
            for xv in 0u32..16 {
                let mut pe = TulipPe::new();
                pe.regs_mut().poke_field(2, 0, 4, xv);
                let s = ge_const(Loc::Reg { reg: 2, lsb: 0, width: 4 }, t, CMP_N);
                s.run_on(&mut pe, &[]);
                assert_eq!(pe.neuron_out(CMP_N), (xv as i64) >= t, "x={xv} t={t}");
            }
        }
    }

    /// maxpool: OR over windows of 1..=12 bits, all patterns for small n.
    #[test]
    fn maxpool_or_matches_or() {
        for n in 1usize..=12 {
            let products: Vec<usize> = (0..n).collect();
            let s = maxpool_or(&products, CMP_N);
            let expected_cycles = if n <= 4 { 1 } else { 1 + (n - 4).div_ceil(3) };
            assert_eq!(s.cycles(), expected_cycles, "n={n}");
            for pattern in [0u32, 1, 1 << (n - 1), (1 << n) - 1, 0b1010 & ((1 << n) - 1)] {
                let mut pe = TulipPe::new();
                s.run_on(&mut pe, &bits_of(pattern, n));
                assert_eq!(pe.neuron_out(CMP_N), pattern != 0, "n={n} pat={pattern:b}");
            }
        }
    }

    /// Fig. 5(b): a 2×2 pooling window is a single cycle.
    #[test]
    fn maxpool_2x2_single_cycle() {
        assert_eq!(maxpool_or(&[0, 1, 2, 3], CMP_N).cycles(), 1);
    }

    /// ReLU: output = x when x ≥ t else 0.
    #[test]
    fn relu_masks_below_threshold() {
        for t in [0i64, 3, 9, 100] {
            for xv in 0u32..16 {
                let mut pe = TulipPe::new();
                pe.regs_mut().poke_field(0, 0, 4, xv);
                let s = relu(Loc::Reg { reg: 0, lsb: 0, width: 4 }, t, 1, 0);
                s.run_on(&mut pe, &[]);
                let expect = if (xv as i64) >= t { xv } else { 0 };
                assert_eq!(pe.regs().peek_field(1, 0, 4), expect, "x={xv} t={t}");
            }
        }
    }

    #[test]
    fn load_stream_roundtrip() {
        let mut pe = TulipPe::new();
        let s = load_stream(0, 0, 8, 3, 4);
        assert_eq!(s.cycles(), 8);
        s.run_on(&mut pe, &bits_of(0xA5, 8));
        assert_eq!(pe.regs().peek_field(3, 4, 8), 0xA5);
    }

    /// Accumulation (Fig. 4c): repeated adds alternating registers.
    #[test]
    fn accumulate_alternating_registers() {
        let mut pe = TulipPe::new();
        // acc in R2 (reg 1), inputs arrive in R1 (reg 0); alternate dst
        // between R4 (reg 3) and R2 (reg 1) per Fig. 4(c).
        let inputs = [5u32, 9, 3, 14, 7];
        let mut acc_loc = Loc::Reg { reg: 1, lsb: 0, width: 4 };
        pe.regs_mut().poke_field(1, 0, 4, 0);
        let mut total = 0u32;
        for (step, &v) in inputs.iter().enumerate() {
            pe.regs_mut().poke_field(0, 0, 4, v);
            let dst = if step % 2 == 0 { 3 } else { 1 };
            let w = acc_loc.width().max(4);
            let s = accumulate(acc_loc, Loc::Reg { reg: 0, lsb: 0, width: 4 }, dst, 0);
            assert_eq!(s.cycles(), w);
            s.run_on(&mut pe, &[]);
            total += v;
            acc_loc = Loc::Reg { reg: dst, lsb: 0, width: (w + 1).min(10) };
            let got = pe.regs().peek_field(dst, 0, acc_loc.width());
            assert_eq!(got, total, "after step {step}");
        }
    }

    #[test]
    #[should_panic(expected = "distinct registers")]
    fn add_same_register_operands_panics() {
        let _ = add(
            Loc::Reg { reg: 0, lsb: 0, width: 4 },
            Loc::Reg { reg: 0, lsb: 8, width: 4 },
            1,
            0,
            SUM_N,
            CARRY_N,
        );
    }
}

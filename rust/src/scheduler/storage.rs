//! Closed-form storage analysis of the RPO adder-tree schedule (§III-B).
//!
//! For a balanced tree whose leaves (level 0) emit 2-bit sums and whose
//! level-`i` nodes emit `i+2`-bit sums, the maximum storage consumed up to
//! and including a level-`i` node satisfies `m_i = (i+1) + m_{i−1}`,
//! `m_0 = 2`, i.e. `m_i = (i² + 3i)/2 + 2`; with the highest level at
//! `⌊log₂N⌋ − 1`, peak storage is `(⌊log₂N⌋² + ⌊log₂N⌋)/2 + 1` —
//! **O(log² N)** bits, which is why a 1023-input neuron fits in the
//! 4 × 16-bit local registers.

use super::adder_tree::AdderTree;

/// `m_i` from the paper's recurrence: maximum storage (bits) used for all
/// computations up to and including a node at level `i`.
pub fn m_i(i: usize) -> usize {
    (i * i + 3 * i) / 2 + 2
}

/// The paper's peak-storage bound for an `N`-input adder tree:
/// `(⌊log₂N⌋² + ⌊log₂N⌋)/2 + 1`.
pub fn paper_peak_bound(n: usize) -> usize {
    let lg = (n as f64).log2().floor() as usize;
    (lg * lg + lg) / 2 + 1
}

/// Symbolic RPO walk of an actual tree shape: returns the exact peak number
/// of live operand bits (ignoring register fragmentation). This validates
/// both the recurrence and the allocator's instrumentation.
pub fn exact_peak_live_bits(n: usize) -> usize {
    let tree = AdderTree::build(n);
    let mut peak = 0usize;
    let mut live = 0usize;
    fn walk(tree: &AdderTree, id: usize, live: &mut usize, peak: &mut usize) -> usize {
        let node = &tree.nodes[id];
        match node.children {
            None => {
                *live += node.width;
                *peak = (*peak).max(*live);
                node.width
            }
            Some((l, r)) => {
                let wl = walk(tree, l, live, peak);
                let wr = walk(tree, r, live, peak);
                // During the combining add, the destination coexists with
                // both operands (bit-serial write while reading).
                *live += node.width;
                *peak = (*peak).max(*live);
                *live -= wl + wr;
                node.width
            }
        }
    }
    walk(&tree, tree.root, &mut live, &mut peak);
    peak
}

/// Storage report for DESIGN.md/EXPERIMENTS.md and the `schedule_viz`
/// example.
#[derive(Debug, Clone, Copy)]
pub struct StorageReport {
    /// Fan-in the report covers.
    pub n: usize,
    /// Exact peak simultaneously-live bits from the RPO walk.
    pub exact_peak_bits: usize,
    /// The paper's analytic upper bound on peak bits.
    pub paper_bound_bits: usize,
    /// Physical register-file capacity (4 × 16 bits).
    pub physical_bits: usize,
}

/// Compute the report for a fan-in.
pub fn report(n: usize) -> StorageReport {
    StorageReport {
        n,
        exact_peak_bits: exact_peak_live_bits(n),
        paper_bound_bits: paper_peak_bound(n),
        physical_bits: crate::pe::NUM_REGS * crate::pe::REG_BITS,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recurrence_closed_form() {
        // m_0 = 2; m_i = i + 1 + m_{i-1}.
        assert_eq!(m_i(0), 2);
        for i in 1..12 {
            assert_eq!(m_i(i), i + 1 + m_i(i - 1));
        }
    }

    /// For exact power-of-two leaf counts (N = 3·2^L) the exact peak equals
    /// the recurrence value at the top level (plus the transient
    /// destination-coexistence the paper's narrative also counts).
    #[test]
    fn exact_peak_matches_recurrence_on_balanced_trees() {
        for l in 1..=6usize {
            let n = 3 * (1 << l);
            let tree = AdderTree::build(n);
            assert_eq!(tree.levels(), l);
            let peak = exact_peak_live_bits(n);
            // The paper's m_i counts the pending left operands plus the
            // current node's output — our exact walk agrees to within the
            // destination width of the root (transient).
            let m = m_i(l);
            assert!(
                peak >= m && peak <= m + tree.root_width(),
                "n={n}: peak {peak} vs m_{l} = {m}"
            );
        }
    }

    #[test]
    fn paper_bound_dominates_exact_peak() {
        for &n in &[6usize, 12, 24, 48, 96, 192, 288, 384, 768, 1023, 2048, 4095] {
            let peak = exact_peak_live_bits(n);
            let bound = paper_peak_bound(n) + paper_peak_bound(n) / 4 + 3;
            assert!(peak <= bound, "n={n}: exact {peak} > relaxed bound {bound}");
        }
    }

    /// The headline claim: O(log²N) — a 1023-input node (Fig. 2b) fits the
    /// physical 64 bits, 2047 still fits, and 4095 is the first size that
    /// exceeds it (root sum 13 bits > the "up to 10-bit addition" the paper
    /// supports directly; beyond this the coordinator chunks the fan-in and
    /// uses the accumulation schedule, §IV-C).
    #[test]
    fn log_squared_scaling() {
        assert!(exact_peak_live_bits(1023) <= 64);
        assert!(exact_peak_live_bits(2047) <= 64);
        let p4095 = exact_peak_live_bits(4095);
        assert!(p4095 > 64 && p4095 <= 80, "{p4095}");
        assert_eq!(report(288).physical_bits, 64);
    }
}

//! The program cache — thread-safe memoization of sequence-generator
//! output (§IV-E brought to serving scale).
//!
//! The hardware has **one** reconfigurable sequence generator whose control
//! stream is broadcast to every TULIP-PE; the simulator equivalent is one
//! program per distinct operation descriptor, shared by `Arc` across every
//! PE — and, since this cache is `Sync`, across every worker thread of the
//! batched inference engine (`coordinator::batch`). Each unique layer shape
//! is scheduled **once per process** instead of once per image per layer:
//! schedule generation runs the backtracking register allocator
//! (`adder_tree::plan_placements`), which is by far the most expensive
//! per-layer setup cost.
//!
//! Reads take a shared `RwLock` guard (the steady state is read-only).
//! Misses are **single-flight**: each descriptor owns a `OnceLock` cell, so
//! when N threads race on a cold key exactly one runs the planner (one
//! miss) while the rest block on the cell and are served the finished
//! program (N−1 hits) — planning happens once per key per process, period,
//! exactly like the hardware broadcasts one control stream.

use super::seqgen::{CachedProgram, OpDesc};
use super::{adder_tree, ops, Loc, Schedule};
use crate::metrics::MetricsRegistry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

/// Point-in-time snapshot of a [`ProgramCache`]'s effectiveness counters —
/// what perf reports embed as their `cache` section.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache — including lookups that arrived
    /// while another thread was building the same key and waited for it.
    pub hits: u64,
    /// Lookups that ran the planner. Builds are single-flight, so N
    /// threads racing on one cold key record exactly **one** miss.
    pub misses: u64,
    /// Distinct programs currently cached.
    pub entries: usize,
    /// Wall-clock nanoseconds spent building programs on the miss path.
    /// A threshold node's first build recurses into its shared sum tree,
    /// whose build time is then counted both on its own and inside its
    /// parent's span — read this as "time the cache saved per future hit",
    /// not as an exact disjoint sum.
    pub planning_ns: u64,
}

impl CacheStats {
    /// Hits as a fraction of all lookups (0 when the cache is untouched).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Planning time in milliseconds.
    pub fn planning_ms(&self) -> f64 {
        self.planning_ns as f64 * 1e-6
    }
}

/// PE-array parameters the generated control streams depend on. Programs
/// cached under one parameter set are only valid for identically shaped
/// PEs, so these are part of the cache identity: callers must not share a
/// cache between differently configured arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArchParams {
    /// Neurons per PE (the `[2,1,1,1;T]` cell count, §IV-A). Must match
    /// the compiled-in `pe::NUM_NEURONS` — checked by
    /// [`ProgramCache::for_arch`].
    pub num_neurons: usize,
    /// Local register width per neuron. Must match `pe::REG_BITS` —
    /// checked by [`ProgramCache::for_arch`].
    pub reg_bits: usize,
    /// Largest fan-in a single adder-tree pass may be asked to sum before
    /// the coordinator must chunk the node (§IV-C). Enforced: asking this
    /// cache for a sum tree or threshold node beyond the limit panics with
    /// a pointer at the chunk-and-accumulate path instead of failing deep
    /// inside the register allocator.
    pub max_tree_fanin: usize,
}

impl Default for ArchParams {
    fn default() -> Self {
        ArchParams {
            num_neurons: crate::pe::NUM_NEURONS,
            reg_bits: crate::pe::REG_BITS,
            max_tree_fanin: 1023,
        }
    }
}

/// Thread-safe schedule cache: `OpDesc` → generated program, keyed under
/// one [`ArchParams`] set. Cheap to share (`Arc<ProgramCache>`); programs
/// themselves are shared by reference, never cloned per PE.
#[derive(Debug, Default)]
pub struct ProgramCache {
    params: ArchParams,
    /// One cell per descriptor: the cell is created under the write lock
    /// (cheap), but the program inside is built via `OnceLock::get_or_init`
    /// *outside* any map lock — the single-flight point.
    map: RwLock<HashMap<OpDesc, Arc<OnceLock<Arc<CachedProgram>>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    planning_ns: AtomicU64,
}

impl ProgramCache {
    /// A fresh cache for the paper's PE geometry.
    pub fn new() -> Self {
        Self::default()
    }

    /// A fresh cache for an explicit PE geometry. The schedule builders
    /// are compiled for the paper's 4-neuron / 16-bit-register PE, so a
    /// geometry that differs from the crate constants is rejected rather
    /// than silently handing out default-geometry programs.
    pub fn for_arch(params: ArchParams) -> Self {
        assert_eq!(
            (params.num_neurons, params.reg_bits),
            (crate::pe::NUM_NEURONS, crate::pe::REG_BITS),
            "schedule builders are compiled for the paper's PE geometry"
        );
        ProgramCache { params, ..Default::default() }
    }

    /// The process-wide shared cache (paper geometry). Every consumer that
    /// does not need private hit/miss accounting should use this one — it
    /// is what makes "schedule once per process" literally true across
    /// batch workers, the analytic model and the bit-true engine.
    pub fn global() -> Arc<ProgramCache> {
        static GLOBAL: OnceLock<Arc<ProgramCache>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| Arc::new(ProgramCache::new())))
    }

    /// The PE geometry this cache's programs were generated for.
    pub fn params(&self) -> ArchParams {
        self.params
    }

    /// Get (or build) the program for an operation descriptor.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use tulip::scheduler::seqgen::OpDesc;
    /// use tulip::scheduler::ProgramCache;
    ///
    /// let cache = ProgramCache::new();
    /// let d = OpDesc::ThresholdNode { n: 9, t_popcount: 4 };
    /// let first = cache.program(&d); // miss: plans the schedule
    /// let again = cache.program(&d); // hit: the same broadcast Arc
    /// assert!(Arc::ptr_eq(&first, &again));
    ///
    /// let s = cache.snapshot();
    /// // One hit; two misses (the threshold node plus its shared sum tree).
    /// assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
    /// assert!(s.planning_ns > 0 && s.hit_rate() > 0.0);
    /// ```
    pub fn program(&self, desc: &OpDesc) -> Arc<CachedProgram> {
        // Fast path: initialized cell under a shared read guard.
        if let Some(cell) = self.map.read().expect("program cache poisoned").get(desc) {
            if let Some(p) = cell.get() {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(p);
            }
        }
        // Create (or fetch) the key's cell, then drop the map lock before
        // building: generation may recurse into `program` (a threshold
        // node shares its sum-tree plan — a *different* key, so the
        // recursion cannot self-deadlock) and can take milliseconds for
        // large fan-ins.
        let cell = {
            let mut map = self.map.write().expect("program cache poisoned");
            Arc::clone(map.entry(desc.clone()).or_default())
        };
        let mut built_here = false;
        let p = cell.get_or_init(|| {
            built_here = true;
            self.misses.fetch_add(1, Ordering::Relaxed);
            let _span = crate::metrics::span("scheduler.plan");
            let t0 = Instant::now();
            let built = Arc::new(self.build(desc));
            self.planning_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            built
        });
        if !built_here {
            // Either the cell was initialized between our read and write
            // guards, or we blocked while the in-flight builder finished;
            // both are served from the cache.
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        Arc::clone(p)
    }

    /// Cycle count for an op (cached; the analytic model's entry point).
    pub fn cycles(&self, desc: &OpDesc) -> u64 {
        self.program(desc).schedule.cycles() as u64
    }

    /// (cache hits, misses) since construction. Builds are single-flight,
    /// so concurrent lookups of one cold key record exactly one miss; the
    /// waiters count as hits.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Number of distinct programs cached (cells still being built by an
    /// in-flight miss don't count until they hold a program).
    pub fn len(&self) -> usize {
        self.map
            .read()
            .expect("program cache poisoned")
            .values()
            .filter(|cell| cell.get().is_some())
            .count()
    }

    /// Whether no program has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the cache's effectiveness counters (hits, misses,
    /// entries, planning time). See the [`ProgramCache::program`] example.
    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
            planning_ns: self.planning_ns.load(Ordering::Relaxed),
        }
    }

    /// Publish the current counters into a metrics registry as gauges
    /// (`scheduler.cache.hits` / `.misses` / `.entries` / `.hit_rate` /
    /// `.planning_ms`). Gauges, not counters, because the cache already
    /// owns the monotonic state — publishing is set-to-latest.
    pub fn publish_to(&self, registry: &MetricsRegistry) {
        let s = self.snapshot();
        registry.gauge("scheduler.cache.hits").set(s.hits as f64);
        registry.gauge("scheduler.cache.misses").set(s.misses as f64);
        registry.gauge("scheduler.cache.entries").set(s.entries as f64);
        registry.gauge("scheduler.cache.hit_rate").set(s.hit_rate());
        registry.gauge("scheduler.cache.planning_ms").set(s.planning_ms());
    }

    fn build(&self, desc: &OpDesc) -> CachedProgram {
        match *desc {
            OpDesc::ThresholdNode { n, t_popcount } => {
                // §Perf: a conv layer has one distinct threshold per OFM
                // channel but a single tree shape, and tree planning (the
                // backtracking register allocator) dominates generation.
                // Share the cached sum-tree program across thresholds and
                // append only the sequential comparison — generation per
                // extra channel drops from a full re-plan to a clone+append.
                let base = self.program(&OpDesc::SumTree { n });
                let sum_loc = base.out_loc.expect("sum tree leaves its result in a register");
                // Clone without the visualization notes: cached programs
                // are executed thousands of times but never pretty-printed,
                // and the per-word String clones dominate the copy cost.
                let mut schedule = Schedule {
                    words: base
                        .schedule
                        .words
                        .iter()
                        .map(|w| crate::pe::ControlWord { note: None, ..w.clone() })
                        .collect(),
                    ext_map: base.schedule.ext_map.clone(),
                };
                let cmp = ops::ge_const(sum_loc, t_popcount, ops::CMP_N);
                schedule.extend(cmp);
                CachedProgram::new(schedule, Some(ops::CMP_N), Some(sum_loc))
            }
            OpDesc::SumTree { n } => {
                assert!(
                    n <= self.params.max_tree_fanin,
                    "fan-in {n} exceeds this architecture's single-pass tree limit of {} — \
                     chunk the node and accumulate (§IV-C), as coordinator::exec::pe_node_cost \
                     does",
                    self.params.max_tree_fanin
                );
                let (schedule, loc, _) = adder_tree::sum_tree(n);
                CachedProgram::new(schedule, None, Some(loc))
            }
            OpDesc::Maxpool { n } => {
                let products: Vec<usize> = (0..n).collect();
                let schedule = ops::maxpool_or(&products, ops::CMP_N);
                CachedProgram::new(schedule, Some(ops::CMP_N), None)
            }
            OpDesc::Relu { w, t } => {
                // Input in R1[0..w], output to R2[0..w].
                let x = Loc::Reg { reg: 0, lsb: 0, width: w };
                let schedule = ops::relu(x, t, 1, 0);
                CachedProgram::new(schedule, None, Some(Loc::Reg { reg: 1, lsb: 0, width: w }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::TulipPe;

    #[test]
    fn hit_returns_the_broadcast_program() {
        let cache = ProgramCache::new();
        let d = OpDesc::ThresholdNode { n: 96, t_popcount: 40 };
        let a = cache.program(&d);
        let b = cache.program(&d);
        assert!(Arc::ptr_eq(&a, &b), "a hit must return the broadcast Arc");
        // ThresholdNode + its shared SumTree: two entries, two misses.
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats(), (1, 2));
    }

    /// A cache hit is indistinguishable from a fresh generation: same
    /// control words, same external demand, same bit-true behaviour.
    #[test]
    fn cached_program_equals_fresh_generation() {
        let warm = ProgramCache::new();
        let d = OpDesc::ThresholdNode { n: 48, t_popcount: 20 };
        let _ = warm.program(&d); // miss: populate
        let hit = warm.program(&d); // hit
        let fresh = ProgramCache::new().program(&d);
        assert_eq!(hit.schedule.words, fresh.schedule.words);
        assert_eq!(hit.schedule.ext_map, fresh.schedule.ext_map);
        assert_eq!(hit.out_neuron, fresh.out_neuron);
        assert_eq!(hit.out_loc, fresh.out_loc);
        let bits: Vec<bool> = (0..48).map(|i| i % 3 != 0).collect();
        let (mut p1, mut p2) = (TulipPe::new(), TulipPe::new());
        hit.schedule.run_on(&mut p1, &bits);
        fresh.schedule.run_on(&mut p2, &bits);
        let (o1, o2) = (hit.out_neuron.unwrap(), fresh.out_neuron.unwrap());
        assert_eq!(p1.neuron_out(o1), p2.neuron_out(o2));
    }

    /// The cache is `Sync`: concurrent consumers all end up holding the
    /// same broadcast `Arc`, even when they race on the initial build.
    #[test]
    fn concurrent_consumers_share_one_program() {
        let cache = Arc::new(ProgramCache::new());
        let d = OpDesc::ThresholdNode { n: 288, t_popcount: 144 };
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let cache = Arc::clone(&cache);
                let d = d.clone();
                std::thread::spawn(move || cache.program(&d))
            })
            .collect();
        let progs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let canonical = cache.program(&d);
        for p in &progs {
            assert!(Arc::ptr_eq(p, &canonical), "all threads must hold the map's entry");
        }
        assert_eq!(cache.len(), 2, "one threshold program + one shared sum tree");
    }

    #[test]
    fn snapshot_matches_legacy_stats_and_times_planning() {
        let cache = ProgramCache::new();
        let d = OpDesc::SumTree { n: 32 };
        let _ = cache.program(&d); // miss
        let _ = cache.program(&d); // hit
        let s = cache.snapshot();
        assert_eq!((s.hits, s.misses), cache.stats());
        assert_eq!(s.entries, cache.len());
        assert!(s.planning_ns > 0, "the miss path must record planning time");
        assert_eq!(s.hit_rate(), 0.5);
        // Warm lookups add no planning time.
        let _ = cache.program(&d);
        assert_eq!(cache.snapshot().planning_ns, s.planning_ns);
        // Publishing mirrors the snapshot into gauges.
        let reg = MetricsRegistry::new();
        cache.publish_to(&reg);
        assert_eq!(reg.gauge("scheduler.cache.entries").get(), s.entries as f64);
        assert_eq!(reg.gauge("scheduler.cache.hit_rate").get(), 0.5);
    }

    #[test]
    fn global_cache_is_a_singleton() {
        let a = ProgramCache::global();
        let b = ProgramCache::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.params(), ArchParams::default());
    }

    #[test]
    fn arch_params_are_cache_identity() {
        let p = ArchParams { max_tree_fanin: 768, ..Default::default() };
        let cache = ProgramCache::for_arch(p);
        assert_eq!(cache.params().max_tree_fanin, 768);
        assert_eq!(cache.params().num_neurons, crate::pe::NUM_NEURONS);
        assert!(cache.is_empty());
        // Within the limit the tightened cache behaves normally.
        let ok = cache.program(&OpDesc::SumTree { n: 768 });
        assert_ne!(ok.schedule.cycles(), 0);
    }

    /// The fan-in limit is enforced, not just recorded: oversized nodes
    /// fail loudly at the cache instead of deep in the register allocator.
    #[test]
    #[should_panic(expected = "single-pass tree limit")]
    fn oversized_fanin_rejected() {
        let params = ArchParams { max_tree_fanin: 768, ..Default::default() };
        let cache = ProgramCache::for_arch(params);
        let _ = cache.program(&OpDesc::ThresholdNode { n: 800, t_popcount: 400 });
    }
}

//! Adder-tree decomposition of a large-fanin threshold function (§III) and
//! its reverse post-order (RPO) schedule on a TULIP-PE (Fig. 2b).
//!
//! The weighted sum `S = Σ w_i x_i` of a BNN node (reduced to a popcount of
//! XNOR products, see `neuron::function`) is decomposed into a balanced
//! binary tree whose leaves sum three product bits (one full-adder cycle)
//! and whose internal nodes perform bit-serial additions of the partial
//! sums. The RPO walk schedules a node only after both subtrees complete,
//! which minimizes peak intermediate storage (§III-B: `m_i = (i²+3i)/2+2`).
//!
//! Register allocation follows the paper's Fig. 4(b) discipline: the two
//! operands of every addition live in **different local registers** (one
//! read port per register file) and the destination is a third register;
//! freed fields are reused immediately, so the whole schedule for nodes up
//! to ≥ 1023 inputs fits the 4 × 16-bit local registers.

use super::ops::{self, CMP_N};
use super::{Loc, Schedule};
use crate::pe::{NUM_REGS, REG_BITS};

/// A node of the adder tree.
#[derive(Debug, Clone)]
pub struct TreeNode {
    /// Leaf: the product indices it sums (1..=3). Internal: empty.
    pub products: Vec<usize>,
    /// Children (internal nodes only).
    pub children: Option<(usize, usize)>,
    /// Output width in bits.
    pub width: usize,
    /// Tree level (leaves = 0). A promoted odd node keeps its level.
    pub level: usize,
}

/// The decomposition of an `n`-input popcount into bounded-fanin adds.
#[derive(Debug, Clone)]
pub struct AdderTree {
    /// All tree nodes, leaves first.
    pub nodes: Vec<TreeNode>,
    /// Index of the root node in `nodes`.
    pub root: usize,
    /// Number of 1-bit inputs (products).
    pub n: usize,
}

impl AdderTree {
    /// Build the balanced decomposition for `n ≥ 1` product bits: `⌈n/3⌉`
    /// leaves, then pairwise combination per level (an odd node is promoted
    /// unchanged, so ragged sizes are handled exactly).
    pub fn build(n: usize) -> Self {
        assert!(n >= 1, "adder tree needs at least one input");
        let mut nodes = Vec::new();
        // Leaves: chunks of 3 product bits (1 full-adder cycle each).
        let mut leaves: Vec<usize> = Vec::new();
        let mut next_product = 0usize;
        while next_product < n {
            let take = (n - next_product).min(3);
            let products: Vec<usize> = (next_product..next_product + take).collect();
            next_product += take;
            nodes.push(TreeNode {
                width: if take == 1 { 1 } else { 2 },
                products,
                children: None,
                level: 0,
            });
            leaves.push(nodes.len() - 1);
        }
        // Recursive left-complete split: the left child covers the largest
        // power-of-two prefix. Unlike pairwise-with-promotion, this keeps
        // every intermediate result short-lived (it is consumed as soon as
        // its sibling completes), which is what lets the RPO schedule fit
        // the 4 × 16-bit register file even for ragged leaf counts.
        fn combine(nodes: &mut Vec<TreeNode>, leaves: &[usize]) -> usize {
            if leaves.len() == 1 {
                return leaves[0];
            }
            let mut split = 1usize;
            while split * 2 < leaves.len() {
                split *= 2;
            }
            let l = combine(nodes, &leaves[..split]);
            let r = combine(nodes, &leaves[split..]);
            let width = nodes[l].width.max(nodes[r].width) + 1;
            let level = nodes[l].level.max(nodes[r].level) + 1;
            nodes.push(TreeNode { products: Vec::new(), children: Some((l, r)), width, level });
            nodes.len() - 1
        }
        let root = combine(&mut nodes, &leaves);
        AdderTree { nodes, root, n }
    }

    /// Cycle count of the RPO schedule for the summation (leaves: 1 cycle;
    /// internal node: `max(w_l, w_r)` cycles). This is the closed form the
    /// analytic performance model uses; `sim` asserts it equals bit-true
    /// execution.
    pub fn sum_cycles(&self) -> u64 {
        self.nodes
            .iter()
            .map(|nd| match nd.children {
                None => 1,
                Some((l, r)) => self.nodes[l].width.max(self.nodes[r].width) as u64,
            })
            .sum()
    }

    /// Width of the root partial sum in bits.
    pub fn root_width(&self) -> usize {
        self.nodes[self.root].width
    }

    /// Number of tree levels (`⌊log2⌋` of the leaf count, §III-B).
    pub fn levels(&self) -> usize {
        self.nodes[self.root].level
    }
}

/// Best-fit contiguous allocator over the 4 × 16-bit local registers.
#[derive(Debug, Clone)]
pub struct RegAlloc {
    /// Bit `i` of `used[r]` set ⇒ R(r+1)[i] is live.
    used: [u16; NUM_REGS],
    /// High-water mark of live bits (storage-analysis instrumentation).
    peak_bits: usize,
    live_bits: usize,
}

impl Default for RegAlloc {
    fn default() -> Self {
        Self::new()
    }
}

impl RegAlloc {
    /// An allocator with the whole register file free.
    pub fn new() -> Self {
        RegAlloc { used: [0; NUM_REGS], peak_bits: 0, live_bits: 0 }
    }

    /// Allocate a contiguous `width`-bit field in any register not listed
    /// in `exclude`. Policy: **first-fit at the lowest address** of the
    /// least-loaded admissible register. Low-address packing keeps the free
    /// space of each register contiguous at the top, which is what lets the
    /// 1023-input Fig. 2(b) schedule fit the 4 × 16-bit file (best-fit
    /// fragments the file and fails around N ≈ 700).
    pub fn alloc(&mut self, width: usize, exclude: &[usize]) -> Option<(usize, usize)> {
        assert!(width >= 1 && width <= REG_BITS);
        let mut best: Option<(usize, usize, u32)> = None; // (reg, lsb, load)
        for reg in 0..NUM_REGS {
            if exclude.contains(&reg) {
                continue;
            }
            let load = self.used[reg].count_ones();
            let mut bit = 0;
            while bit < REG_BITS {
                if self.used[reg] >> bit & 1 != 0 {
                    bit += 1;
                    continue;
                }
                let start = bit;
                while bit < REG_BITS && self.used[reg] >> bit & 1 == 0 {
                    bit += 1;
                }
                let hole = bit - start;
                if hole >= width {
                    let better = match best {
                        None => true,
                        Some((_, blsb, bload)) => (load, start) < (bload, blsb),
                    };
                    if better {
                        best = Some((reg, start, load));
                    }
                    break; // first fit within this register
                }
            }
        }
        let (reg, lsb, _) = best?;
        let mask = (((1u32 << width) - 1) << lsb) as u16;
        self.used[reg] |= mask;
        self.live_bits += width;
        self.peak_bits = self.peak_bits.max(self.live_bits);
        Some((reg, lsb))
    }

    /// Allocate `width` contiguous bits in a *specific* register (first fit
    /// at the lowest address), or `None` if it has no adequate hole.
    pub fn alloc_in(&mut self, reg: usize, width: usize) -> Option<(usize, usize)> {
        assert!(width >= 1 && width <= REG_BITS && reg < NUM_REGS);
        let mut bit = 0;
        while bit < REG_BITS {
            if self.used[reg] >> bit & 1 != 0 {
                bit += 1;
                continue;
            }
            let start = bit;
            while bit < REG_BITS && self.used[reg] >> bit & 1 == 0 {
                bit += 1;
            }
            if bit - start >= width {
                let mask = (((1u32 << width) - 1) << start) as u16;
                self.used[reg] |= mask;
                self.live_bits += width;
                self.peak_bits = self.peak_bits.max(self.live_bits);
                return Some((reg, start));
            }
        }
        None
    }

    /// Re-mark a specific field as live (backtracking undo).
    pub fn mark(&mut self, reg: usize, lsb: usize, width: usize) {
        let mask = (((1u32 << width) - 1) << lsb) as u16;
        debug_assert_eq!(self.used[reg] & mask, 0, "mark over live bits");
        self.used[reg] |= mask;
        self.live_bits += width;
        self.peak_bits = self.peak_bits.max(self.live_bits);
    }

    /// Release a field.
    pub fn free(&mut self, reg: usize, lsb: usize, width: usize) {
        let mask = (((1u32 << width) - 1) << lsb) as u16;
        debug_assert_eq!(self.used[reg] & mask, mask, "double free");
        self.used[reg] &= !mask;
        self.live_bits -= width;
    }

    /// Release a field given as a [`Loc`] (no-op for non-register locations).
    pub fn free_loc(&mut self, loc: Loc) {
        if let Loc::Reg { reg, lsb, width } = loc {
            self.free(reg, lsb, width);
        }
    }

    /// Peak simultaneously-live bits observed.
    pub fn peak_bits(&self) -> usize {
        self.peak_bits
    }

    /// Currently-live bits.
    pub fn live_bits(&self) -> usize {
        self.live_bits
    }
}

/// A fully scheduled threshold node: the Fig. 2(b) program for one BNN
/// neuron of arbitrary fan-in.
#[derive(Debug, Clone)]
pub struct ThresholdNodeSchedule {
    /// Complete control-word program (tree summation + final comparison).
    pub schedule: Schedule,
    /// Neuron whose latch holds `f = [S ≥ T']` after the last cycle.
    pub out_neuron: usize,
    /// Where the root partial sum `S` resides.
    pub sum_loc: Loc,
    /// Cycles spent in the adder tree.
    pub tree_cycles: u64,
    /// Cycles spent in the final threshold comparison.
    pub cmp_cycles: u64,
    /// Peak local-register bits live during the schedule.
    pub peak_storage_bits: usize,
}

impl ThresholdNodeSchedule {
    /// Tree + comparison cycles (= schedule length).
    pub fn total_cycles(&self) -> u64 {
        self.schedule.cycles() as u64
    }
}

/// Emit the RPO schedule computing the popcount of `n` product bits,
/// leaving the sum in a register. Returns the schedule, the sum location
/// and the allocator (for storage statistics).
pub fn sum_tree(n: usize) -> (Schedule, Loc, RegAlloc) {
    let tree = AdderTree::build(n);
    let order = rpo_order(&tree);
    let (placement, alloc) = plan_placements(&tree, &order)
        .unwrap_or_else(|| panic!("register allocation infeasible for n={n}"));
    let mut sched = Schedule::new();
    for &(id, _) in &order {
        let node = &tree.nodes[id];
        let (reg, lsb) = placement[id];
        match node.children {
            None => sched.extend(ops::leaf(&node.products, reg, lsb)),
            Some((l, r)) => {
                let lloc = loc_of(&tree, &placement, l);
                let rloc = loc_of(&tree, &placement, r);
                sched.extend(ops::add(lloc, rloc, reg, lsb, ops::SUM_N, ops::CARRY_N));
            }
        }
    }
    let root_loc = loc_of(&tree, &placement, tree.root);
    (sched, root_loc, alloc)
}

fn loc_of(tree: &AdderTree, placement: &[(usize, usize)], id: usize) -> Loc {
    let (reg, lsb) = placement[id];
    Loc::Reg { reg, lsb, width: tree.nodes[id].width }
}

/// Reverse post-order (left, right, node) with each node's sibling id.
fn rpo_order(tree: &AdderTree) -> Vec<(usize, Option<usize>)> {
    let mut order = Vec::with_capacity(tree.nodes.len());
    fn walk(
        tree: &AdderTree,
        id: usize,
        sibling: Option<usize>,
        order: &mut Vec<(usize, Option<usize>)>,
    ) {
        if let Some((l, r)) = tree.nodes[id].children {
            walk(tree, l, Some(r), order);
            walk(tree, r, Some(l), order);
        }
        order.push((id, sibling));
    }
    walk(tree, tree.root, None, &mut order);
    order
}

/// Register placement for every tree node by backtracking search over the
/// RPO completion order.
///
/// Hardware rules (one read port per register, Fig. 4b discipline):
/// * a node's destination register differs from both operand registers;
/// * sibling results live in different registers (the parent reads both in
///   the same cycle);
/// * fields are contiguous within one 16-bit register.
///
/// Candidates are tried colored-register-first (children of register `r` →
/// `(r+1)`, `(r+2)` mod 4 — the assignment that satisfies the port rules by
/// construction), so the search almost never backtracks; the backtracking
/// is the completeness net for deep ragged trees. The plan is computed once
/// per distinct fan-in and cached by the sequence generator (§IV-E).
fn plan_placements(
    tree: &AdderTree,
    order: &[(usize, Option<usize>)],
) -> Option<(Vec<(usize, usize)>, RegAlloc)> {
    // Deterministic color per node: root 0; children of color c → c+1, c+2.
    let mut color = vec![0usize; tree.nodes.len()];
    fn colorize(tree: &AdderTree, id: usize, c: usize, color: &mut [usize]) {
        color[id] = c;
        if let Some((l, r)) = tree.nodes[id].children {
            colorize(tree, l, (c + 1) % NUM_REGS, color);
            colorize(tree, r, (c + 2) % NUM_REGS, color);
        }
    }
    colorize(tree, tree.root, 0, &mut color);

    let mut placement: Vec<Option<(usize, usize)>> = vec![None; tree.nodes.len()];
    let mut alloc = RegAlloc::new();
    let mut steps = 0usize;
    const STEP_CAP: usize = 2_000_000;

    fn rec(
        tree: &AdderTree,
        order: &[(usize, Option<usize>)],
        i: usize,
        color: &[usize],
        placement: &mut Vec<Option<(usize, usize)>>,
        alloc: &mut RegAlloc,
        steps: &mut usize,
    ) -> bool {
        if i == order.len() {
            return true;
        }
        let (id, sibling) = order[i];
        let node = &tree.nodes[id];
        let mut excl: Vec<usize> = Vec::with_capacity(3);
        if let Some((l, r)) = node.children {
            excl.push(placement[l].unwrap().0);
            excl.push(placement[r].unwrap().0);
        }
        if let Some(s) = sibling {
            if let Some((sreg, _)) = placement[s] {
                excl.push(sreg);
            }
        }
        // Candidate registers: preferred color first, then the rest.
        let pref = color[id];
        let mut cands = [pref, 0, 1, 2, 3];
        let mut len = 1;
        for r in 0..NUM_REGS {
            if r != pref {
                cands[len] = r;
                len += 1;
            }
        }
        for &reg in &cands[..len] {
            if excl.contains(&reg) {
                continue;
            }
            *steps += 1;
            if *steps > STEP_CAP {
                return false;
            }
            let Some((_, lsb)) = alloc.alloc_in(reg, node.width) else { continue };
            placement[id] = Some((reg, lsb));
            // The operands die once the destination is written.
            if let Some((l, r)) = node.children {
                let (lr, ll) = placement[l].unwrap();
                let (rr, rl) = placement[r].unwrap();
                alloc.free(lr, ll, tree.nodes[l].width);
                alloc.free(rr, rl, tree.nodes[r].width);
                if rec(tree, order, i + 1, color, placement, alloc, steps) {
                    return true;
                }
                // Undo child frees.
                alloc.mark(lr, ll, tree.nodes[l].width);
                alloc.mark(rr, rl, tree.nodes[r].width);
            } else if rec(tree, order, i + 1, color, placement, alloc, steps) {
                return true;
            }
            alloc.free(reg, lsb, node.width);
            placement[id] = None;
        }
        false
    }

    if rec(tree, order, 0, &color, &mut placement, &mut alloc, &mut steps) {
        Some((placement.into_iter().map(|p| p.unwrap()).collect(), alloc))
    } else {
        None
    }
}

/// The complete program for a BNN node with `n` XNOR products and popcount
/// threshold `t_popcount` (see `ThresholdFunction::popcount_threshold`):
/// adder tree in RPO, then the sequential comparison `S ≥ T'` (Fig. 5a).
pub fn threshold_node(n: usize, t_popcount: i64) -> ThresholdNodeSchedule {
    let (mut sched, sum_loc, alloc) = sum_tree(n);
    let tree_cycles = sched.cycles() as u64;
    let cmp = ops::ge_const(sum_loc, t_popcount, CMP_N);
    let cmp_cycles = cmp.cycles() as u64;
    sched.extend(cmp);
    ThresholdNodeSchedule {
        schedule: sched,
        out_neuron: CMP_N,
        sum_loc,
        tree_cycles,
        cmp_cycles,
        peak_storage_bits: alloc.peak_bits(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::TulipPe;

    fn random_bits(n: usize, seed: u64) -> Vec<bool> {
        // Small deterministic LCG; avoids pulling rand into unit tests.
        let mut state = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                state >> 33 & 1 != 0
            })
            .collect()
    }

    #[test]
    fn tree_shape_288() {
        let t = AdderTree::build(288);
        // 96 leaves, then 48+24+12+6+3 → (2 +) … pairwise with promotion.
        let leaves = t.nodes.iter().filter(|n| n.children.is_none()).count();
        assert_eq!(leaves, 96);
        assert!(t.root_width() >= 9, "must hold values up to 288");
    }

    /// The popcount computed through the full bit-true PE execution equals
    /// `count_ones` for a spread of sizes, including ragged ones.
    #[test]
    fn sum_tree_equals_popcount() {
        for &n in &[1usize, 2, 3, 4, 5, 7, 9, 17, 31, 48, 96, 100, 288] {
            for seed in 0..3u64 {
                let bits = random_bits(n, seed + 1);
                let (sched, loc, _) = sum_tree(n);
                assert!(sched.validate().is_ok(), "n={n}");
                let mut pe = TulipPe::new();
                sched.run_on(&mut pe, &bits);
                let expect = bits.iter().filter(|&&b| b).count() as u32;
                if let Loc::Reg { reg, lsb, width } = loc {
                    assert_eq!(pe.regs().peek_field(reg, lsb, width), expect, "n={n} seed={seed}");
                } else {
                    panic!("sum not in register");
                }
            }
        }
    }

    /// Full threshold node: f = [popcount ≥ T'] bit-true for many (n, T').
    #[test]
    fn threshold_node_bit_true() {
        for &n in &[3usize, 9, 27, 100, 288] {
            for &t in &[0i64, 1, (n / 2) as i64, n as i64, n as i64 + 5] {
                let prog = threshold_node(n, t);
                assert!(prog.schedule.validate().is_ok());
                let bits = random_bits(n, n as u64 * 31 + t as u64 + 7);
                let mut pe = TulipPe::new();
                prog.schedule.run_on(&mut pe, &bits);
                let pc = bits.iter().filter(|&&b| b).count() as i64;
                assert_eq!(pe.neuron_out(prog.out_neuron), pc >= t, "n={n} t={t}");
            }
        }
    }

    /// Table II anchor: cycle count for the 288-input node (3×3 kernel,
    /// 32 IFMs). The paper reports 441 under its microarchitecture; our
    /// Fig.4-faithful schedule lands in the same regime (documented in
    /// EXPERIMENTS.md §Table II) — assert the invariant bounds.
    #[test]
    fn cycles_288_in_expected_regime() {
        let prog = threshold_node(288, 145);
        let c = prog.total_cycles();
        assert!(c >= 300 && c <= 600, "288-input node took {c} cycles");
        assert_eq!(prog.tree_cycles, AdderTree::build(288).sum_cycles());
    }

    /// §III-B storage: peak live bits follow the O(log²N) law. The paper's
    /// closed form `(⌊lg N⌋² + ⌊lg N⌋)/2 + 1` counts pending operands only;
    /// our exact accounting adds the transient coexistence of a node's
    /// destination with its operands (≤ root width), so the bound is the
    /// paper's plus one destination field.
    #[test]
    fn storage_within_paper_bound() {
        for &n in &[6usize, 12, 24, 48, 96, 192, 288, 384, 768, 1023] {
            let (_, loc, alloc) = sum_tree(n);
            let lg = (n as f64).log2().floor() as usize;
            let bound = (lg * lg + lg) / 2 + 1 + loc.width();
            assert!(
                alloc.peak_bits() <= bound,
                "n={n}: peak {} > bound {}",
                alloc.peak_bits(),
                bound
            );
            assert!(alloc.peak_bits() <= NUM_REGS * REG_BITS, "exceeds physical registers");
        }
    }

    /// The Fig. 2(b) example: a 1023-input threshold function fits the
    /// 4×16-bit local registers.
    #[test]
    fn fig2_1023_inputs_fit() {
        let prog = threshold_node(1023, 512);
        assert!(prog.peak_storage_bits <= 64);
        let bits = random_bits(1023, 99);
        let mut pe = TulipPe::new();
        prog.schedule.run_on(&mut pe, &bits);
        let pc = bits.iter().filter(|&&b| b).count() as i64;
        assert_eq!(pe.neuron_out(prog.out_neuron), pc >= 512);
    }

    #[test]
    fn allocator_best_fit_and_free() {
        let mut a = RegAlloc::new();
        let (r0, l0) = a.alloc(16, &[]).unwrap();
        assert_eq!((r0, l0), (0, 0));
        let (r1, _) = a.alloc(4, &[0]).unwrap();
        assert_ne!(r1, 0);
        a.free(r1, 0, 4);
        assert_eq!(a.live_bits(), 16);
        // exclusion of all regs → None
        assert!(a.alloc(1, &[0, 1, 2, 3]).is_none());
        // width larger than any hole → None
        let mut b = RegAlloc::new();
        for r in 0..NUM_REGS {
            b.alloc(16, &(0..r).collect::<Vec<_>>()).unwrap();
        }
        assert!(b.alloc(1, &[]).is_none());
    }

    #[test]
    fn sum_cycles_matches_bit_true_execution() {
        for &n in &[5usize, 48, 288] {
            let (sched, _, _) = sum_tree(n);
            assert_eq!(sched.cycles() as u64, AdderTree::build(n).sum_cycles(), "n={n}");
        }
    }
}

//! The reconfigurable sequence generator (§IV-E).
//!
//! "For the TULIP-PEs, a reconfigurable sequence generator is used. This
//! sequence generator follows the RPO schedule, and controls the local
//! registers and the multiplexers of the TULIP-PEs. The control signals are
//! broadcast to all the processing units."
//!
//! In the simulator this is a **schedule factory with a cache**: control
//! streams are generated once per distinct operation descriptor and
//! broadcast (shared by reference) to every PE in the array. The cache is
//! also the L3 hot-path optimization — schedule generation is O(N) work
//! that would otherwise sit inside the per-window loop.


use super::ops;
use super::{Loc, Schedule};
use std::collections::HashMap;
use std::sync::Arc;

/// Descriptor of an operation the controller can sequence.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpDesc {
    /// `n`-input popcount-and-threshold node (binary conv / FC neuron).
    ThresholdNode { n: usize, t_popcount: i64 },
    /// `n`-input popcount only (partial pass of a multi-pass accumulation).
    SumTree { n: usize },
    /// OR-maxpool over `n` window bits.
    Maxpool { n: usize },
    /// `w`-bit ReLU with threshold `t`.
    Relu { w: usize, t: i64 },
}

/// The sequence generator: generates + caches control-word programs.
#[derive(Debug, Default)]
pub struct SequenceGenerator {
    cache: HashMap<OpDesc, Arc<CachedProgram>>,
    hits: u64,
    misses: u64,
}

/// A cached program together with the metadata the runners need.
#[derive(Debug)]
pub struct CachedProgram {
    pub schedule: Schedule,
    /// Neuron holding the 1-bit result (threshold node / maxpool), if any.
    pub out_neuron: Option<usize>,
    /// Register field holding the multi-bit result, if any.
    pub out_loc: Option<Loc>,
}

impl SequenceGenerator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get (or build) the program for an operation.
    pub fn program(&mut self, desc: &OpDesc) -> Arc<CachedProgram> {
        if let Some(p) = self.cache.get(desc) {
            self.hits += 1;
            return Arc::clone(p);
        }
        self.misses += 1;
        let prog = Arc::new(self.build(desc));
        self.cache.insert(desc.clone(), Arc::clone(&prog));
        prog
    }

    fn build(&mut self, desc: &OpDesc) -> CachedProgram {
        match *desc {
            OpDesc::ThresholdNode { n, t_popcount } => {
                // §Perf: a conv layer has one distinct threshold per OFM
                // channel but a single tree shape, and tree planning (the
                // backtracking register allocator) dominates generation.
                // Share the cached sum-tree program across thresholds and
                // append only the sequential comparison — generation per
                // extra channel drops from a full re-plan to a clone+append.
                let base = self.program(&OpDesc::SumTree { n });
                let sum_loc = base.out_loc.expect("sum tree leaves its result in a register");
                // Clone without the visualization notes: cached programs
                // are executed thousands of times but never pretty-printed,
                // and the per-word String clones dominate the copy cost.
                let mut schedule = Schedule {
                    words: base
                        .schedule
                        .words
                        .iter()
                        .map(|w| crate::pe::ControlWord { note: None, ..w.clone() })
                        .collect(),
                    ext_map: base.schedule.ext_map.clone(),
                };
                let cmp = ops::ge_const(sum_loc, t_popcount, ops::CMP_N);
                schedule.extend(cmp);
                CachedProgram {
                    schedule,
                    out_neuron: Some(ops::CMP_N),
                    out_loc: Some(sum_loc),
                }
            }
            OpDesc::SumTree { n } => {
                let (schedule, loc, _) = super::adder_tree::sum_tree(n);
                CachedProgram { schedule, out_neuron: None, out_loc: Some(loc) }
            }
            OpDesc::Maxpool { n } => {
                let products: Vec<usize> = (0..n).collect();
                let schedule = ops::maxpool_or(&products, ops::CMP_N);
                CachedProgram { schedule, out_neuron: Some(ops::CMP_N), out_loc: None }
            }
            OpDesc::Relu { w, t } => {
                // Input in R1[0..w], output to R2[0..w].
                let x = Loc::Reg { reg: 0, lsb: 0, width: w };
                let schedule = ops::relu(x, t, 1, 0);
                CachedProgram {
                    schedule,
                    out_neuron: None,
                    out_loc: Some(Loc::Reg { reg: 1, lsb: 0, width: w }),
                }
            }
        }
    }

    /// Cycle count for an op (cached; the analytic model's entry point).
    pub fn cycles(&mut self, desc: &OpDesc) -> u64 {
        self.program(desc).schedule.cycles() as u64
    }

    /// (cache hits, misses) — exercised by the hot-path bench.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hits_on_repeat() {
        let mut sg = SequenceGenerator::new();
        let d = OpDesc::ThresholdNode { n: 48, t_popcount: 20 };
        let p1 = sg.program(&d);
        let p2 = sg.program(&d);
        assert!(Arc::ptr_eq(&p1, &p2));
        // Building the threshold node also populated the shared sum-tree
        // entry (one extra miss); the repeat is a pure hit.
        assert_eq!(sg.cache_stats(), (1, 2));
    }

    /// §Perf: two thresholds over the same fan-in share the sum-tree plan —
    /// the second ThresholdNode build hits the SumTree cache.
    #[test]
    fn thresholds_share_tree_plan() {
        let mut sg = SequenceGenerator::new();
        let a = sg.program(&OpDesc::ThresholdNode { n: 96, t_popcount: 40 });
        let (h0, m0) = sg.cache_stats();
        let b = sg.program(&OpDesc::ThresholdNode { n: 96, t_popcount: 60 });
        let (h1, m1) = sg.cache_stats();
        assert_eq!(m1 - m0, 1, "only the new threshold entry misses");
        assert_eq!(h1 - h0, 1, "the sum tree is a cache hit");
        // Same tree prefix, different comparison epilogues.
        assert_eq!(a.schedule.cycles(), b.schedule.cycles());
        assert_ne!(a.schedule.words, b.schedule.words);
    }

    #[test]
    fn distinct_descriptors_distinct_programs() {
        let mut sg = SequenceGenerator::new();
        let a = sg.program(&OpDesc::SumTree { n: 12 });
        let b = sg.program(&OpDesc::SumTree { n: 13 });
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.schedule.cycles(), 0);
    }

    #[test]
    fn cycles_consistent_with_program() {
        let mut sg = SequenceGenerator::new();
        let d = OpDesc::Maxpool { n: 9 };
        let c = sg.cycles(&d);
        assert_eq!(c, sg.program(&d).schedule.cycles() as u64);
        assert_eq!(c, 1 + (9u64 - 4).div_ceil(3));
    }

    #[test]
    fn relu_program_shape() {
        let mut sg = SequenceGenerator::new();
        let p = sg.program(&OpDesc::Relu { w: 8, t: 5 });
        assert_eq!(p.schedule.cycles(), 16);
        assert_eq!(p.out_loc, Some(Loc::Reg { reg: 1, lsb: 0, width: 8 }));
    }
}

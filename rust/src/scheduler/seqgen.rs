//! The reconfigurable sequence generator (§IV-E).
//!
//! "For the TULIP-PEs, a reconfigurable sequence generator is used. This
//! sequence generator follows the RPO schedule, and controls the local
//! registers and the multiplexers of the TULIP-PEs. The control signals are
//! broadcast to all the processing units."
//!
//! In the simulator this is a **handle over a schedule cache**
//! ([`super::cache::ProgramCache`]): control streams are generated once per
//! distinct operation descriptor and broadcast (shared by reference) to
//! every PE in the array. The cache is also the L3 hot-path optimization —
//! schedule generation is O(N) planner work that would otherwise sit inside
//! the per-window loop. A generator built with [`SequenceGenerator::new`]
//! owns a private cache (useful for hit/miss accounting in tests); one
//! built with [`SequenceGenerator::with_cache`] shares programs with every
//! other holder of that cache — across threads, in the batched engine.

use super::cache::ProgramCache;
use super::{Loc, Schedule};
use crate::pe::{PeStats, TulipPe};
use std::sync::{Arc, OnceLock};

/// Descriptor of an operation the controller can sequence.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpDesc {
    /// `n`-input popcount-and-threshold node (binary conv / FC neuron).
    ThresholdNode { n: usize, t_popcount: i64 },
    /// `n`-input popcount only (partial pass of a multi-pass accumulation).
    SumTree { n: usize },
    /// OR-maxpool over `n` window bits.
    Maxpool { n: usize },
    /// `w`-bit ReLU with threshold `t`.
    Relu { w: usize, t: i64 },
}

/// The sequence generator: a handle that generates + caches control-word
/// programs through its [`ProgramCache`].
#[derive(Debug, Default)]
pub struct SequenceGenerator {
    cache: Arc<ProgramCache>,
}

/// A cached program together with the metadata the runners need.
#[derive(Debug)]
pub struct CachedProgram {
    /// The control-word program.
    pub schedule: Schedule,
    /// Neuron holding the 1-bit result (threshold node / maxpool), if any.
    pub out_neuron: Option<usize>,
    /// Register field holding the multi-bit result, if any.
    pub out_loc: Option<Loc>,
    /// Lazily measured per-run activity (see [`Self::unit_stats`]).
    unit_stats: OnceLock<PeStats>,
}

impl CachedProgram {
    /// Bundle a schedule with its output metadata.
    pub fn new(schedule: Schedule, out_neuron: Option<usize>, out_loc: Option<Loc>) -> Self {
        CachedProgram { schedule, out_neuron, out_loc, unit_stats: OnceLock::new() }
    }

    /// Activity counters for exactly one run of this program on one PE.
    ///
    /// A schedule's activity is control-flow determined: which neurons
    /// evaluate, which are gated, and which register bits are read or
    /// written each cycle depend only on the control words, never on the
    /// data bits flowing through them. So one measurement — a scalar
    /// [`TulipPe`] run on dummy products — is exact for every run, and the
    /// bit-sliced engine multiplies it by its modelled run count
    /// ([`PeStats::scaled`]) instead of counting per step. Measured once
    /// per cached program, then memoized.
    pub fn unit_stats(&self) -> PeStats {
        *self.unit_stats.get_or_init(|| {
            let mut pe = TulipPe::new();
            let dummy = vec![false; self.schedule.product_arity()];
            self.schedule.run_on(&mut pe, &dummy);
            pe.stats()
        })
    }
}

impl SequenceGenerator {
    /// A generator with its own private cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// A generator sharing an existing (possibly process-global) cache.
    pub fn with_cache(cache: Arc<ProgramCache>) -> Self {
        SequenceGenerator { cache }
    }

    /// The underlying cache (share it with other generators / threads).
    pub fn cache(&self) -> Arc<ProgramCache> {
        Arc::clone(&self.cache)
    }

    /// Get (or build) the program for an operation.
    pub fn program(&mut self, desc: &OpDesc) -> Arc<CachedProgram> {
        self.cache.program(desc)
    }

    /// Cycle count for an op (cached; the analytic model's entry point).
    pub fn cycles(&mut self, desc: &OpDesc) -> u64 {
        self.cache.cycles(desc)
    }

    /// (cache hits, misses) — exercised by the hot-path bench.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hits_on_repeat() {
        let mut sg = SequenceGenerator::new();
        let d = OpDesc::ThresholdNode { n: 48, t_popcount: 20 };
        let p1 = sg.program(&d);
        let p2 = sg.program(&d);
        assert!(Arc::ptr_eq(&p1, &p2));
        // Building the threshold node also populated the shared sum-tree
        // entry (one extra miss); the repeat is a pure hit.
        assert_eq!(sg.cache_stats(), (1, 2));
    }

    /// §Perf: two thresholds over the same fan-in share the sum-tree plan —
    /// the second ThresholdNode build hits the SumTree cache.
    #[test]
    fn thresholds_share_tree_plan() {
        let mut sg = SequenceGenerator::new();
        let a = sg.program(&OpDesc::ThresholdNode { n: 96, t_popcount: 40 });
        let (h0, m0) = sg.cache_stats();
        let b = sg.program(&OpDesc::ThresholdNode { n: 96, t_popcount: 60 });
        let (h1, m1) = sg.cache_stats();
        assert_eq!(m1 - m0, 1, "only the new threshold entry misses");
        assert_eq!(h1 - h0, 1, "the sum tree is a cache hit");
        // Same tree prefix, different comparison epilogues.
        assert_eq!(a.schedule.cycles(), b.schedule.cycles());
        assert_ne!(a.schedule.words, b.schedule.words);
    }

    #[test]
    fn distinct_descriptors_distinct_programs() {
        let mut sg = SequenceGenerator::new();
        let a = sg.program(&OpDesc::SumTree { n: 12 });
        let b = sg.program(&OpDesc::SumTree { n: 13 });
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.schedule.cycles(), 0);
    }

    #[test]
    fn cycles_consistent_with_program() {
        let mut sg = SequenceGenerator::new();
        let d = OpDesc::Maxpool { n: 9 };
        let c = sg.cycles(&d);
        assert_eq!(c, sg.program(&d).schedule.cycles() as u64);
        assert_eq!(c, 1 + (9u64 - 4).div_ceil(3));
    }

    #[test]
    fn relu_program_shape() {
        let mut sg = SequenceGenerator::new();
        let p = sg.program(&OpDesc::Relu { w: 8, t: 5 });
        assert_eq!(p.schedule.cycles(), 16);
        assert_eq!(p.out_loc, Some(Loc::Reg { reg: 1, lsb: 0, width: 8 }));
    }

    /// `unit_stats` is data-independent: the memoized dummy-data
    /// measurement equals a fresh measurement on all-ones products.
    #[test]
    fn unit_stats_is_data_independent() {
        let mut sg = SequenceGenerator::new();
        for desc in [
            OpDesc::ThresholdNode { n: 37, t_popcount: 11 },
            OpDesc::SumTree { n: 20 },
            OpDesc::Maxpool { n: 9 },
        ] {
            let prog = sg.program(&desc);
            let cached = prog.unit_stats();
            let mut pe = crate::pe::TulipPe::new();
            let ones = vec![true; prog.schedule.product_arity()];
            prog.schedule.run_on(&mut pe, &ones);
            assert_eq!(cached, pe.stats(), "{desc:?}");
            assert_eq!(cached.cycles, prog.schedule.cycles() as u64, "{desc:?}");
        }
    }

    /// Generators built over the same cache share programs by pointer; a
    /// private generator does not.
    #[test]
    fn shared_cache_shares_programs() {
        let cache = Arc::new(super::super::cache::ProgramCache::new());
        let mut a = SequenceGenerator::with_cache(Arc::clone(&cache));
        let mut b = SequenceGenerator::with_cache(cache);
        let d = OpDesc::SumTree { n: 27 };
        assert!(Arc::ptr_eq(&a.program(&d), &b.program(&d)));
        let mut private = SequenceGenerator::new();
        assert!(!Arc::ptr_eq(&private.program(&d), &b.program(&d)));
    }
}

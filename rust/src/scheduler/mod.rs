//! Scheduling — decomposing BNN operations onto a TULIP-PE.
//!
//! §III–IV of the paper: a threshold function with large fan-in is
//! decomposed into a balanced **adder tree** of bounded-fanin nodes, the
//! tree is walked in **reverse post-order (RPO)** to minimize intermediate
//! storage, and every node — additions, the accumulator, the sequential
//! comparator, batch-norm, maxpool and ReLU — is a short sequence of
//! control words for the same four-neuron PE.
//!
//! * [`ops`] — builders for every primitive schedule (Fig. 4/5).
//! * [`adder_tree`] — tree construction, RPO walk, register allocation, and
//!   the complete threshold-node schedule (Fig. 2b).
//! * [`storage`] — the closed-form storage analysis of §III-B.
//! * [`cache`] — the thread-safe program cache (schedule once per process).
//! * [`seqgen`] — the reconfigurable sequence generator (a cache handle).

pub mod adder_tree;
pub mod cache;
pub mod cla;
pub mod ops;
pub mod seqgen;
pub mod storage;

pub use cache::{ArchParams, CacheStats, ProgramCache};

use crate::pe::{ControlWord, TulipPe};

/// What an external input channel must carry on a given cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExtSpec {
    /// Bit `i` of the caller's product / operand vector.
    Product(usize),
    /// A literal bit (constant operands, padding).
    Lit(bool),
}

/// A complete PE schedule: the control-word stream plus a per-cycle map of
/// what each external channel consumes. Produced by the builders in this
/// module; executed bit-true by [`TulipPe::step`] and priced analytically by
/// `sim::perf` — both from the *same* object.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// One control word per cycle.
    pub words: Vec<ControlWord>,
    /// `ext_map[cycle][channel]` — demand on external channels. Shorter
    /// rows mean the remaining channels are unused that cycle.
    pub ext_map: Vec<Vec<ExtSpec>>,
}

impl Schedule {
    /// An empty schedule.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of cycles.
    pub fn cycles(&self) -> usize {
        self.words.len()
    }

    /// Append one word with its external demand.
    pub fn push(&mut self, word: ControlWord, ext: Vec<ExtSpec>) {
        self.words.push(word);
        self.ext_map.push(ext);
    }

    /// Concatenate another schedule.
    pub fn extend(&mut self, other: Schedule) {
        self.words.extend(other.words);
        self.ext_map.extend(other.ext_map);
    }

    /// Remap every [`ExtSpec::Product`] index through `f` (used when a node
    /// schedule built for local product indices is embedded into a layer-
    /// global product vector).
    pub fn remap_products(&mut self, f: impl Fn(usize) -> usize) {
        for row in &mut self.ext_map {
            for e in row {
                if let ExtSpec::Product(i) = e {
                    *i = f(*i);
                }
            }
        }
    }

    /// Validate every control word against the hardware constraints.
    pub fn validate(&self) -> std::result::Result<(), crate::Error> {
        for (i, w) in self.words.iter().enumerate() {
            w.validate().map_err(|e| crate::Error::InvalidSchedule(format!("cycle {i}: {e}")))?;
        }
        Ok(())
    }

    /// Execute bit-true on a PE, materializing external inputs from a
    /// product/operand bit vector.
    ///
    /// Hot path (§Perf): external-channel rows are bounded by the PE's
    /// physical input fan-out, so they materialize into a stack buffer —
    /// this loop performs no heap allocation.
    pub fn run_on(&self, pe: &mut TulipPe, products: &[bool]) {
        const MAX_EXT: usize = 8;
        let mut ext_buf = [false; MAX_EXT];
        for (word, row) in self.words.iter().zip(&self.ext_map) {
            debug_assert!(row.len() <= MAX_EXT, "ext row wider than physical channels");
            for (slot, e) in ext_buf.iter_mut().zip(row) {
                *slot = match *e {
                    ExtSpec::Product(i) => {
                        assert!(i < products.len(), "product index {i} out of range");
                        products[i]
                    }
                    ExtSpec::Lit(b) => b,
                };
            }
            pe.step(word, &ext_buf[..row.len()]);
        }
    }

    /// Highest product index demanded (+1), i.e. the product-vector length
    /// this schedule expects.
    pub fn product_arity(&self) -> usize {
        self.ext_map
            .iter()
            .flatten()
            .filter_map(|e| match e {
                ExtSpec::Product(i) => Some(i + 1),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }

    /// Total non-gated neuron evaluations (analytic energy, no execution).
    pub fn neuron_evals(&self) -> u64 {
        self.words.iter().map(|w| w.active_neurons() as u64).sum()
    }

    /// Total register bit accesses (reads via srcs/buses + writes).
    pub fn reg_accesses(&self) -> (u64, u64) {
        let mut reads = 0u64;
        let mut writes = 0u64;
        for w in &self.words {
            for bus in [w.bus_b, w.bus_c] {
                if bus.reads_reg().is_some() {
                    reads += 1;
                }
            }
            for n in &w.neurons {
                if n.gated {
                    continue;
                }
                for s in [n.a, n.d] {
                    if s.reads_reg().is_some() {
                        reads += 1;
                    }
                }
            }
            writes += w.writes.len() as u64;
        }
        (reads, writes)
    }
}

/// Where a multi-bit operand lives, for the schedule builders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loc {
    /// `width` bits in register `reg` starting at `lsb` (little-endian).
    Reg { reg: usize, lsb: usize, width: usize },
    /// A compile-time constant (e.g. the threshold in a comparison).
    Const { value: u32, width: usize },
    /// Streamed from external channels: bit `i` arrives on channel
    /// `channel` at the cycle that consumes it, as product index
    /// `base + i`.
    Stream { channel: usize, base: usize, width: usize },
}

impl Loc {
    /// Operand width in bits.
    pub fn width(&self) -> usize {
        match *self {
            Loc::Reg { width, .. } | Loc::Const { width, .. } | Loc::Stream { width, .. } => width,
        }
    }

    /// Register id if register-resident.
    pub fn reg(&self) -> Option<usize> {
        match *self {
            Loc::Reg { reg, .. } => Some(reg),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::TulipPe;

    #[test]
    fn empty_schedule_noops() {
        let s = Schedule::new();
        assert_eq!(s.cycles(), 0);
        assert!(s.validate().is_ok());
        let mut pe = TulipPe::new();
        s.run_on(&mut pe, &[]);
        assert_eq!(pe.stats().cycles, 0);
    }

    #[test]
    fn product_arity_tracks_max_index() {
        let mut s = Schedule::new();
        s.push(ControlWord::idle(), vec![ExtSpec::Product(4), ExtSpec::Lit(true)]);
        s.push(ControlWord::idle(), vec![ExtSpec::Product(7)]);
        assert_eq!(s.product_arity(), 8);
        s.remap_products(|i| i + 10);
        assert_eq!(s.product_arity(), 18);
    }

    #[test]
    fn loc_accessors() {
        let l = Loc::Reg { reg: 2, lsb: 3, width: 5 };
        assert_eq!(l.width(), 5);
        assert_eq!(l.reg(), Some(2));
        assert_eq!(Loc::Const { value: 3, width: 2 }.reg(), None);
    }
}

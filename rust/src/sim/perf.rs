//! Consistency layer between the analytic performance model
//! (`coordinator::exec`) and bit-true execution (`sim::cycle`).
//!
//! Both derive from the same `Schedule` objects, so per-node cycle counts
//! and activity counters must agree exactly; these helpers measure both
//! sides and are exercised by tests and the `hotpath` bench.

use crate::coordinator::exec::{pe_node_cost, NodeCost};
use crate::pe::TulipPe;
use crate::scheduler::seqgen::{OpDesc, SequenceGenerator};
use crate::util::Rng;

/// Measure a threshold node bit-true: run it on a fresh PE with random
/// products and return (cycles, neuron_evals, reg_accesses).
pub fn measure_node_bit_true(n: usize, t_popcount: i64, seed: u64) -> (u64, u64, u64) {
    let mut sg = SequenceGenerator::new();
    let prog = sg.program(&OpDesc::ThresholdNode { n, t_popcount });
    let mut rng = Rng::seed_from_u64(seed);
    let products: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.5)).collect();
    let mut pe = TulipPe::new();
    prog.schedule.run_on(&mut pe, &products);
    let s = pe.stats();
    (s.cycles, s.neuron_evals, s.reg_reads + s.reg_writes)
}

/// Analytic counterpart via the coordinator's node-cost model.
pub fn predict_node(n: usize) -> NodeCost {
    let mut sg = SequenceGenerator::new();
    pe_node_cost(&mut sg, n, n)
}

/// Assert agreement for a fan-in (used by tests; returns the cost for
/// reporting). The threshold is chosen non-degenerate so the comparison
/// schedule is exercised.
pub fn check_consistency(n: usize) -> NodeCost {
    let predicted = predict_node(n);
    let (cycles, evals, _regs) = measure_node_bit_true(n, (n / 2) as i64, 7);
    assert_eq!(predicted.cycles, cycles, "cycle mismatch at n={n}");
    assert_eq!(predicted.neuron_evals, evals, "eval mismatch at n={n}");
    predicted
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The analytic model's cycles/evals equal bit-true execution for a
    /// spread of fan-ins — the invariant that pins the whole table pipeline
    /// to the hardware model.
    #[test]
    fn analytic_equals_bit_true() {
        for &n in &[9usize, 27, 72, 144, 288, 576] {
            let c = check_consistency(n);
            assert!(c.cycles > 0);
        }
    }

    /// Register accesses: the schedule's static count equals the executed
    /// count (reads via buses/inputs + writes).
    #[test]
    fn reg_access_static_matches_dynamic() {
        let mut sg = SequenceGenerator::new();
        for &n in &[27usize, 288] {
            let prog = sg.program(&OpDesc::ThresholdNode { n, t_popcount: (n / 2) as i64 });
            let (r, w) = prog.schedule.reg_accesses();
            let (_, _, dynamic) = measure_node_bit_true(n, (n / 2) as i64, 3);
            assert_eq!(r + w, dynamic, "n={n}");
        }
    }
}

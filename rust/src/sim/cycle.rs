//! Bit-true, cycle-accurate execution of BNN layers on the TULIP-PE array.
//!
//! Every output activation is computed by streaming XNOR products through
//! the *actual control words* of the threshold-node schedule (Fig. 2b) on a
//! simulated PE — no arithmetic shortcuts — and cross-checked against the
//! functional reference in tests. This engine powers the end-to-end
//! example (`examples/e2e_inference.rs`) and the schedule-level unit tests;
//! full-size networks use the analytic model (`coordinator::exec`), whose
//! cycle counts this engine validates.
//!
//! Two execution paths produce identical results (asserted by
//! `tests/bitslice.rs`):
//!
//! * the **scalar** path (`conv_bin_cycle` / `maxpool_cycle` /
//!   `fc_bin_cycle`): one `bool` at a time per stateful [`TulipPe`] — the
//!   readable reference oracle;
//! * the **bit-sliced** path (`conv_bin_sliced` / `maxpool_sliced` /
//!   `fc_bin_sliced`): 64 lockstep lanes per `u64` word on a [`PeSlice`],
//!   one pass of bitwise logic per broadcast control word. Legal because
//!   the paper's own invariant (§IV-E) is that every PE runs the identical
//!   broadcast schedule; the simulator packs 64 such executions — output
//!   pixels for conv/pool, output neurons for FC — into each word.
//!   Activity counters are credited analytically (per-program
//!   [`unit_stats`](crate::scheduler::seqgen::CachedProgram::unit_stats)
//!   × run count), which is exact because schedule activity is
//!   control-flow determined.
//!
//! [`BatchExecutor`](crate::coordinator::BatchExecutor) selects between
//! them via [`ForwardEngine`].
//!
//! [`TulipPe`]: crate::pe::TulipPe
//! [`PeSlice`]: crate::pe::slice::PeSlice

use crate::arch::unit::{xnor_product_word, xnor_products_into, PeArray, SlicedArray};
use crate::bnn::bitpack::{LaneWeights, PackedWeights};
use crate::bnn::tensor::{BinWeights, BitTensor};
use crate::bnn::{Layer, Network};
use crate::pe::slice::LANES;
use crate::pe::PeStats;
use crate::scheduler::seqgen::{OpDesc, SequenceGenerator};
use crate::scheduler::Loc;

/// Result of a bit-true layer execution.
#[derive(Debug, Clone)]
pub struct CycleResult {
    /// The layer's output activation tensor.
    pub output: BitTensor,
    /// Aggregated PE activity.
    pub stats: PeStats,
    /// Wall-clock cycles (PEs run in lockstep; idle PEs are clock-gated).
    pub cycles: u64,
}

/// Per-layer observability record of a whole-network forward pass: where
/// the cycles and the PE activity went. Produced by [`forward_bin_cycle`];
/// the batched engine merges these across images. The records partition
/// the network exactly: `Σ layer.cycles == ForwardResult::cycles` and
/// `Σ layer.stats == ForwardResult::stats` (asserted by `tests/metrics.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerObs {
    /// Layer name from the network description.
    pub name: String,
    /// `"conv"`, `"conv+pool"` (fused max-pool folded into its conv
    /// layer's record) or `"fc"`.
    pub kind: &'static str,
    /// Lockstep wall-clock cycles spent in this layer.
    pub cycles: u64,
    /// PE activity delta attributable to this layer.
    pub stats: PeStats,
}

impl LayerObs {
    /// Accumulate another image's record for the same layer (the batched
    /// engine's per-layer aggregate).
    pub fn merge(&mut self, other: &LayerObs) {
        debug_assert_eq!(self.name, other.name, "merging records of different layers");
        self.cycles += other.cycles;
        self.stats.merge(&other.stats);
    }

    /// This layer's neuron utilization (see [`PeStats::utilization`]).
    pub fn utilization(&self) -> f64 {
        self.stats.utilization()
    }
}

/// Execute a binary conv layer bit-true on the PE array. One PE per OFM
/// channel; the window broadcast is shared (Fig. 6). Returns the
/// pre-pooling output map.
pub fn conv_bin_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    layer: &Layer,
    weights: &BinWeights,
) -> CycleResult {
    assert!(layer.is_binary() && layer.is_conv());
    assert_eq!(input.c, layer.z1);
    let (x2, y2) = layer.output_spatial();
    let mut out = BitTensor::zeros(y2, x2, layer.z2);
    let num_pes = array.num_pes();
    let mut wall_cycles = 0u64;
    let mut products: Vec<bool> = Vec::with_capacity(layer.fanin());
    let mut window: Vec<bool> = Vec::with_capacity(layer.fanin());

    for batch_base in (0..layer.z2).step_by(num_pes) {
        let batch = (layer.z2 - batch_base).min(num_pes);
        // Hoist the per-channel programs out of the pixel loop (§Perf):
        // the sequence generator broadcasts one control stream per node
        // descriptor, exactly as the hardware controller does.
        let progs: Vec<_> = (0..batch)
            .map(|i| {
                sg.program(&OpDesc::ThresholdNode {
                    n: layer.fanin(),
                    t_popcount: weights.thresholds[batch_base + i],
                })
            })
            .collect();
        for oy in 0..y2 {
            for ox in 0..x2 {
                input.window_into(oy, ox, layer.k, layer.stride, layer.padding, &mut window);
                let mut batch_cycles = 0u64;
                for (i, prog) in progs.iter().enumerate() {
                    let ch = batch_base + i;
                    xnor_products_into(&window, weights.filter(ch), &mut products);
                    let pe = array.pe_mut(i);
                    prog.schedule.run_on(pe, &products);
                    out.set(oy, ox, ch, pe.neuron_out(prog.out_neuron.unwrap()));
                    batch_cycles = batch_cycles.max(prog.schedule.cycles() as u64);
                }
                wall_cycles += batch_cycles;
            }
        }
    }
    CycleResult { output: out, stats: array.stats(), cycles: wall_cycles }
}

/// Bit-true max-pooling on the PEs (OR schedule, Fig. 5b).
pub fn maxpool_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    k: usize,
    stride: usize,
) -> CycleResult {
    let oh = (input.h - k) / stride + 1;
    let ow = (input.w - k) / stride + 1;
    let mut out = BitTensor::zeros(oh, ow, input.c);
    let prog = sg.program(&OpDesc::Maxpool { n: k * k });
    let num_pes = array.num_pes();
    let mut wall_cycles = 0u64;
    // Hoisted out of the per-pixel loop (§Perf): one reused window buffer
    // instead of an allocation per (pixel, channel).
    let mut window: Vec<bool> = Vec::with_capacity(k * k);
    for ch_base in (0..input.c).step_by(num_pes) {
        let batch = (input.c - ch_base).min(num_pes);
        for oy in 0..oh {
            for ox in 0..ow {
                for i in 0..batch {
                    let ch = ch_base + i;
                    window.clear();
                    for ky in 0..k {
                        for kx in 0..k {
                            window.push(input.get(oy * stride + ky, ox * stride + kx, ch));
                        }
                    }
                    let pe = array.pe_mut(i);
                    prog.schedule.run_on(pe, &window);
                    out.set(oy, ox, ch, pe.neuron_out(prog.out_neuron.unwrap()));
                }
                wall_cycles += prog.schedule.cycles() as u64;
            }
        }
    }
    CycleResult { output: out, stats: array.stats(), cycles: wall_cycles }
}

/// Bit-true binary FC layer: one PE per output neuron, batched over the
/// array. Returns the binarized outputs; `scores` additionally recovers the
/// raw popcount from the PE register file (used by the classifier head).
pub fn fc_bin_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &[bool],
    layer: &Layer,
    weights: &BinWeights,
) -> (Vec<bool>, Vec<i64>, u64) {
    assert!(layer.is_fc());
    assert_eq!(input.len(), layer.z1);
    let num_pes = array.num_pes();
    let mut bits = vec![false; layer.z2];
    let mut scores = vec![0i64; layer.z2];
    let mut wall_cycles = 0u64;
    // Hoisted out of the batch loop (§Perf): the product buffer is reused
    // across neurons, and each chunk's programs are fetched once instead of
    // once per neuron per lookup.
    let mut products: Vec<bool> = Vec::with_capacity(layer.z1);
    for batch_base in (0..layer.z2).step_by(num_pes) {
        let batch = (layer.z2 - batch_base).min(num_pes);
        let progs: Vec<_> = (0..batch)
            .map(|i| {
                sg.program(&OpDesc::ThresholdNode {
                    n: layer.z1,
                    t_popcount: weights.thresholds[batch_base + i],
                })
            })
            .collect();
        let mut batch_cycles = 0u64;
        for (i, prog) in progs.iter().enumerate() {
            let ch = batch_base + i;
            xnor_products_into(input, weights.filter(ch), &mut products);
            let pe = array.pe_mut(i);
            prog.schedule.run_on(pe, &products);
            bits[ch] = pe.neuron_out(prog.out_neuron.unwrap());
            // The raw sum remains in the register file at `out_loc` — read
            // it back for the classifier head.
            if let Some(crate::scheduler::Loc::Reg { reg, lsb, width }) = prog.out_loc {
                scores[ch] = pe.regs().peek_field(reg, lsb, width) as i64;
            }
            batch_cycles = batch_cycles.max(prog.schedule.cycles() as u64);
        }
        wall_cycles += batch_cycles;
    }
    (bits, scores, wall_cycles)
}

/// Which execution path [`BatchExecutor`](crate::coordinator::BatchExecutor)
/// drives the bit-true simulation with. Both produce bit-identical
/// [`ForwardResult`]s (scores, cycles, per-layer and per-PE [`PeStats`]) —
/// asserted by `tests/bitslice.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum ForwardEngine {
    /// One `bool` per PE per step — the readable reference oracle.
    Scalar,
    /// 64 lockstep lanes per `u64` word — the fast path (default).
    #[default]
    BitSliced,
}

impl ForwardEngine {
    /// Stable lowercase name, used as a metrics tag and in perf reports.
    pub fn name(&self) -> &'static str {
        match self {
            ForwardEngine::Scalar => "scalar",
            ForwardEngine::BitSliced => "bit_sliced",
        }
    }
}

/// Per-layer weight packings for the bit-sliced engine, prepared once per
/// network (the hardware analogue: weights are loaded into the kernel
/// buffer once per layer, not re-fetched per pixel).
#[derive(Debug, Clone)]
pub struct SlicedWeights {
    layers: Vec<LayerPack>,
}

/// Conv layers pack each filter along its fan-in ([`PackedWeights`], sign
/// bits indexed per product); FC layers transpose across output channels
/// ([`LaneWeights`], one lane word per product per 64-channel group).
#[derive(Debug, Clone)]
enum LayerPack {
    Conv(PackedWeights),
    Fc(LaneWeights),
}

impl SlicedWeights {
    /// Pack every layer of a network.
    pub fn pack(net: &Network, weights: &[BinWeights]) -> Self {
        assert_eq!(net.layers.len(), weights.len(), "one weight set per layer");
        let layers = net
            .layers
            .iter()
            .zip(weights)
            .map(|(l, w)| {
                if l.is_conv() {
                    LayerPack::Conv(PackedWeights::pack(w))
                } else {
                    LayerPack::Fc(LaneWeights::pack(w))
                }
            })
            .collect();
        SlicedWeights { layers }
    }
}

/// Bit-sliced binary conv: 64 output pixels per lane word, one schedule run
/// per (pixel-group, channel). Bit-identical to [`conv_bin_cycle`] in
/// output, wall-clock cycles and per-PE activity.
///
/// The window gather is shared by every channel of a pixel group (the
/// broadcast of Fig. 6); activity is credited to the same modelled PE the
/// scalar path would use (`ch % num_pes`), once per pixel it computes.
pub fn conv_bin_sliced(
    arr: &mut SlicedArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    layer: &Layer,
    weights: &BinWeights,
    packed: &PackedWeights,
) -> CycleResult {
    assert!(layer.is_binary() && layer.is_conv());
    assert_eq!(input.c, layer.z1);
    assert_eq!(packed.filters.len(), layer.z2, "packed weights must match the layer");
    let (x2, y2) = layer.output_spatial();
    let mut out = BitTensor::zeros(y2, x2, layer.z2);
    let num_pes = arr.num_pes();
    let pixels = x2 * y2;
    let progs: Vec<_> = (0..layer.z2)
        .map(|ch| {
            sg.program(&OpDesc::ThresholdNode {
                n: layer.fanin(),
                t_popcount: weights.thresholds[ch],
            })
        })
        .collect();

    // Accounting, replicated analytically from the scalar path: each chunk
    // of `num_pes` channels runs in lockstep per pixel (wall = slowest
    // program in the chunk), and channel `ch` executes on modelled PE
    // `ch % num_pes`, once per output pixel.
    let mut wall_cycles = 0u64;
    for chunk in progs.chunks(num_pes) {
        let slowest = chunk.iter().map(|p| p.schedule.cycles() as u64).max().unwrap_or(0);
        wall_cycles += pixels as u64 * slowest;
    }
    for (ch, prog) in progs.iter().enumerate() {
        arr.credit(ch % num_pes, &prog.unit_stats(), pixels as u64);
    }

    // Compute: gather each 64-pixel window group once, then run every
    // channel's program over it with word-level XNOR products.
    let mut window_words: Vec<u64> = Vec::new();
    for start in (0..pixels).step_by(LANES) {
        let group = start..(start + LANES).min(pixels);
        input.window_lanes_into(
            x2,
            layer.k,
            layer.stride,
            layer.padding,
            group.clone(),
            &mut window_words,
        );
        for (ch, prog) in progs.iter().enumerate() {
            let filter = &packed.filters[ch];
            let slice = arr.slice_mut();
            slice.run(&prog.schedule, |p| xnor_product_word(window_words[p], filter.get(p)));
            let outw = slice.neuron_word(prog.out_neuron.expect("threshold node has an output"));
            for (j, pixel) in group.clone().enumerate() {
                out.set(pixel / x2, pixel % x2, ch, outw >> j & 1 != 0);
            }
        }
    }
    CycleResult { output: out, stats: arr.stats(), cycles: wall_cycles }
}

/// Bit-sliced max-pooling: 64 output pixels of one channel per lane word.
/// Bit-identical to [`maxpool_cycle`] in output, cycles and activity.
pub fn maxpool_sliced(
    arr: &mut SlicedArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    k: usize,
    stride: usize,
) -> CycleResult {
    let oh = (input.h - k) / stride + 1;
    let ow = (input.w - k) / stride + 1;
    let mut out = BitTensor::zeros(oh, ow, input.c);
    let prog = sg.program(&OpDesc::Maxpool { n: k * k });
    let num_pes = arr.num_pes();
    let pixels = oh * ow;

    // Scalar accounting: every chunk of `num_pes` channels pays the pool
    // program once per pixel; channel `ch` runs on PE `ch % num_pes`.
    let wall_cycles = (input.c.div_ceil(num_pes) * pixels) as u64 * prog.schedule.cycles() as u64;
    let unit = prog.unit_stats();
    for ch in 0..input.c {
        arr.credit(ch % num_pes, &unit, pixels as u64);
    }

    let mut window_words: Vec<u64> = Vec::new();
    for ch in 0..input.c {
        for start in (0..pixels).step_by(LANES) {
            let group = start..(start + LANES).min(pixels);
            input.pool_lanes_into(ow, k, stride, ch, group.clone(), &mut window_words);
            let slice = arr.slice_mut();
            slice.run(&prog.schedule, |p| window_words[p]);
            let outw = slice.neuron_word(prog.out_neuron.expect("maxpool has an output neuron"));
            for (j, pixel) in group.clone().enumerate() {
                out.set(pixel / ow, pixel % ow, ch, outw >> j & 1 != 0);
            }
        }
    }
    CycleResult { output: out, stats: arr.stats(), cycles: wall_cycles }
}

/// Bit-sliced binary FC: 64 output *neurons* per lane word.
///
/// All channels share one sum-tree shape, so the engine runs the shared
/// [`OpDesc::SumTree`] program once per 64-channel group — products come
/// from the channel-transposed [`LaneWeights`] XNORed against the
/// broadcast input bit — then reads each lane's popcount from the tree's
/// output register field and applies the per-channel threshold. This is
/// exactly the value the scalar path reads back for `scores` (the
/// comparison epilogue appended by the threshold-node program writes no
/// registers), so scores and bits match the scalar path bit for bit; wall
/// cycles and activity are still accounted from the full per-channel
/// threshold-node programs, as the modelled hardware runs them.
pub fn fc_bin_sliced(
    arr: &mut SlicedArray,
    sg: &mut SequenceGenerator,
    input: &[bool],
    layer: &Layer,
    weights: &BinWeights,
    lanes_w: &LaneWeights,
) -> (Vec<bool>, Vec<i64>, u64) {
    assert!(layer.is_fc());
    assert_eq!(input.len(), layer.z1);
    assert_eq!((lanes_w.z2, lanes_w.fanin), (layer.z2, layer.z1), "lane weights must match");
    let num_pes = arr.num_pes();
    let mut bits = vec![false; layer.z2];
    let mut scores = vec![0i64; layer.z2];

    let progs: Vec<_> = (0..layer.z2)
        .map(|ch| {
            sg.program(&OpDesc::ThresholdNode {
                n: layer.z1,
                t_popcount: weights.thresholds[ch],
            })
        })
        .collect();
    let mut wall_cycles = 0u64;
    for chunk in progs.chunks(num_pes) {
        wall_cycles += chunk.iter().map(|p| p.schedule.cycles() as u64).max().unwrap_or(0);
    }
    for (ch, prog) in progs.iter().enumerate() {
        arr.credit(ch % num_pes, &prog.unit_stats(), 1);
    }

    let tree = sg.program(&OpDesc::SumTree { n: layer.z1 });
    let Some(Loc::Reg { reg, lsb, width }) = tree.out_loc else {
        unreachable!("sum tree leaves its result in a register");
    };
    for wi in 0..layer.z2.div_ceil(LANES) {
        let slice = arr.slice_mut();
        slice.run(&tree.schedule, |p| xnor_product_word(lanes_w.word(wi, p), input[p]));
        for j in 0..(layer.z2 - wi * LANES).min(LANES) {
            let ch = wi * LANES + j;
            let pc = slice.peek_field_lane(reg, lsb, width, j) as i64;
            scores[ch] = pc;
            bits[ch] = pc >= weights.thresholds[ch];
        }
    }
    (bits, scores, wall_cycles)
}

/// Result of a whole-network bit-true forward pass.
#[derive(Debug, Clone)]
pub struct ForwardResult {
    /// Raw final-layer popcount scores (argmax = predicted class).
    pub scores: Vec<i64>,
    /// Chip cycles summed over every layer (lockstep wall clock).
    pub cycles: u64,
    /// PE activity for this image alone — the array's counters are reset on
    /// entry, so consecutive calls yield independently summable records.
    pub stats: PeStats,
    /// Per-layer breakdown: partitions `cycles` and `stats` exactly.
    pub layers: Vec<LayerObs>,
    /// Per-PE activity in array-flattened index order (same indexing as
    /// [`PeArray::pe_mut`]) — the source for per-PE utilization reports.
    pub per_pe: Vec<PeStats>,
}

/// Run a whole **binary** network bit-true on the PE array: conv layers
/// (with their fused max-pool) then the FC stack, returning the raw scores
/// of the final layer. This is the per-image unit of work of the batched
/// inference engine (`coordinator::batch`); integer layers are out of scope
/// here exactly as they are for the TULIP-PEs (§V-C routes them to MACs).
///
/// Exposed through [`Model::forward_scalar`](crate::bnn::Model::forward_scalar);
/// the raw `(net, weights)` entry point survives as the deprecated
/// [`forward_bin_cycle`] shim.
pub(crate) fn forward_scalar_impl(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    net: &Network,
    weights: &[BinWeights],
) -> ForwardResult {
    assert_eq!(net.layers.len(), weights.len(), "one weight set per layer");
    array.reset_stats();
    let mut cycles = 0u64;
    let mut layers: Vec<LayerObs> = Vec::with_capacity(net.layers.len());
    let mut act = input.clone();
    let mut flat: Option<Vec<bool>> = None;
    for (i, (layer, w)) in net.layers.iter().zip(weights).enumerate() {
        let last = i + 1 == net.layers.len();
        let stats_before = array.stats();
        let cycles_before = cycles;
        if layer.is_conv() {
            assert!(layer.is_binary(), "forward_bin_cycle handles binary networks only");
            assert!(
                flat.is_none(),
                "conv layer '{}' cannot follow an FC layer (chain topology, §I)",
                layer.name
            );
            let r = conv_bin_cycle(array, sg, &act, layer, w);
            cycles += r.cycles;
            act = r.output;
            let kind = if layer.pool.is_some() { "conv+pool" } else { "conv" };
            if let Some((pk, ps)) = layer.pool {
                let p = maxpool_cycle(array, sg, &act, pk, ps);
                cycles += p.cycles;
                act = p.output;
            }
            layers.push(LayerObs {
                name: layer.name.clone(),
                kind,
                cycles: cycles - cycles_before,
                stats: array.stats().delta(&stats_before),
            });
        } else {
            assert!(layer.is_binary(), "forward_bin_cycle handles binary networks only");
            let input_flat = flat.take().unwrap_or_else(|| act.flatten());
            let (bits, scores, fc_cycles) = fc_bin_cycle(array, sg, &input_flat, layer, w);
            cycles += fc_cycles;
            layers.push(LayerObs {
                name: layer.name.clone(),
                kind: "fc",
                cycles: cycles - cycles_before,
                stats: array.stats().delta(&stats_before),
            });
            if last {
                return ForwardResult {
                    scores,
                    cycles,
                    stats: array.stats(),
                    layers,
                    per_pe: array.per_pe_stats(),
                };
            }
            flat = Some(bits);
        }
    }
    panic!("network must end in an FC layer");
}

/// Bit-sliced whole-network forward pass — the lane-parallel counterpart of
/// [`forward_scalar_impl`], bit-identical in scores, cycles, per-layer
/// records and per-PE activity (asserted by `tests/bitslice.rs`). `packed`
/// must come from [`SlicedWeights::pack`] on the same `(net, weights)`.
///
/// Exposed through [`Model::forward_sliced`](crate::bnn::Model::forward_sliced),
/// which also owns the lazily-built packing; the raw tuple entry point
/// survives as the deprecated [`forward_bin_sliced`] shim.
pub(crate) fn forward_sliced_impl(
    arr: &mut SlicedArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    net: &Network,
    weights: &[BinWeights],
    packed: &SlicedWeights,
) -> ForwardResult {
    assert_eq!(net.layers.len(), weights.len(), "one weight set per layer");
    assert_eq!(net.layers.len(), packed.layers.len(), "one packing per layer");
    arr.reset_stats();
    let mut cycles = 0u64;
    let mut layers: Vec<LayerObs> = Vec::with_capacity(net.layers.len());
    let mut act = input.clone();
    let mut flat: Option<Vec<bool>> = None;
    for (i, (layer, w)) in net.layers.iter().zip(weights).enumerate() {
        let last = i + 1 == net.layers.len();
        let stats_before = arr.stats();
        let cycles_before = cycles;
        if layer.is_conv() {
            assert!(layer.is_binary(), "forward_bin_sliced handles binary networks only");
            assert!(
                flat.is_none(),
                "conv layer '{}' cannot follow an FC layer (chain topology, §I)",
                layer.name
            );
            let LayerPack::Conv(pw) = &packed.layers[i] else {
                panic!("layer '{}' packed as FC but described as conv", layer.name);
            };
            let r = conv_bin_sliced(arr, sg, &act, layer, w, pw);
            cycles += r.cycles;
            act = r.output;
            let kind = if layer.pool.is_some() { "conv+pool" } else { "conv" };
            if let Some((pk, ps)) = layer.pool {
                let p = maxpool_sliced(arr, sg, &act, pk, ps);
                cycles += p.cycles;
                act = p.output;
            }
            layers.push(LayerObs {
                name: layer.name.clone(),
                kind,
                cycles: cycles - cycles_before,
                stats: arr.stats().delta(&stats_before),
            });
        } else {
            assert!(layer.is_binary(), "forward_bin_sliced handles binary networks only");
            let LayerPack::Fc(lw) = &packed.layers[i] else {
                panic!("layer '{}' packed as conv but described as FC", layer.name);
            };
            let input_flat = flat.take().unwrap_or_else(|| act.flatten());
            let (bits, scores, fc_cycles) = fc_bin_sliced(arr, sg, &input_flat, layer, w, lw);
            cycles += fc_cycles;
            layers.push(LayerObs {
                name: layer.name.clone(),
                kind: "fc",
                cycles: cycles - cycles_before,
                stats: arr.stats().delta(&stats_before),
            });
            if last {
                return ForwardResult {
                    scores,
                    cycles,
                    stats: arr.stats(),
                    layers,
                    per_pe: arr.per_pe_stats(),
                };
            }
            flat = Some(bits);
        }
    }
    panic!("network must end in an FC layer");
}

/// Deprecated tuple-shaped entry point — build a
/// [`Model`](crate::bnn::Model) and call
/// [`Model::forward_scalar`](crate::bnn::Model::forward_scalar) instead.
#[deprecated(
    since = "0.2.0",
    note = "build a bnn::Model and call Model::forward_scalar; removed next release"
)]
#[doc(hidden)]
pub fn forward_bin_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    net: &Network,
    weights: &[BinWeights],
) -> ForwardResult {
    forward_scalar_impl(array, sg, input, net, weights)
}

/// Deprecated tuple-shaped entry point — build a
/// [`Model`](crate::bnn::Model) and call
/// [`Model::forward_sliced`](crate::bnn::Model::forward_sliced) instead
/// (the model owns the packing, so the `packed` argument disappears).
#[deprecated(
    since = "0.2.0",
    note = "build a bnn::Model and call Model::forward_sliced; removed next release"
)]
#[doc(hidden)]
pub fn forward_bin_sliced(
    arr: &mut SlicedArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    net: &Network,
    weights: &[BinWeights],
    packed: &SlicedWeights,
) -> ForwardResult {
    forward_sliced_impl(arr, sg, input, net, weights, packed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::layer::LayerKind;
    use crate::bnn::reference;
    use crate::bnn::tiny_bnn;

    fn small_array() -> PeArray {
        PeArray::new(2, 4) // 8 PEs keeps tests fast
    }

    /// Bit-true conv equals the functional reference on random tensors.
    #[test]
    fn conv_bit_true_matches_reference() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (6, 6, 4), 3, 1, 1, 10, None);
        let input = BitTensor::random(6, 6, 4, 11);
        let weights = BinWeights::random(10, layer.fanin(), 5);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let got = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        let expect = reference::conv_bin(&input, &layer, &weights);
        assert_eq!(got.output, expect);
        assert!(got.cycles > 0 && got.stats.neuron_evals > 0);
    }

    /// Stride-2, no-padding geometry also matches.
    #[test]
    fn conv_strided_matches_reference() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (8, 8, 2), 3, 2, 0, 3, None);
        let input = BitTensor::random(8, 8, 2, 3);
        let weights = BinWeights::random(3, layer.fanin(), 8);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let got = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        assert_eq!(got.output, reference::conv_bin(&input, &layer, &weights));
    }

    #[test]
    fn maxpool_bit_true_matches_reference() {
        let input = BitTensor::random(8, 8, 6, 21);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let got = maxpool_cycle(&mut array, &mut sg, &input, 2, 2);
        assert_eq!(got.output, reference::maxpool(&input, 2, 2));
        // AlexNet-style 3×3/2 overlapping pool too.
        let got3 = maxpool_cycle(&mut array, &mut sg, &input, 3, 2);
        assert_eq!(got3.output, reference::maxpool(&input, 3, 2));
    }

    #[test]
    fn fc_bit_true_matches_reference() {
        let layer = Layer::fc("f", LayerKind::FcBin, 64, 12);
        let weights = BinWeights::random(12, 64, 9);
        let input: Vec<bool> = (0..64).map(|i| i % 5 != 0).collect();
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let (bits, scores, cycles) = fc_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        assert_eq!(bits, reference::fc_bin(&input, &layer, &weights));
        assert_eq!(scores, reference::fc_scores(&input, &layer, &weights));
        assert!(cycles > 0);
    }

    /// The whole-network forward pass equals the functional reference and
    /// resets its activity accounting per call.
    #[test]
    fn forward_bin_matches_reference() {
        let net = tiny_bnn(8, 4, 3);
        let weights: Vec<BinWeights> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 90 + i as u64))
            .collect();
        let input = BitTensor::random(8, 8, 4, 17);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let a = forward_scalar_impl(&mut array, &mut sg, &input, &net, &weights);
        assert_eq!(a.scores, reference::forward_scores(&net, &input, &weights));
        assert!(a.cycles > 0 && a.stats.neuron_evals > 0);
        // Per-image accounting: a second identical pass reports identical
        // (not accumulated) stats, even though the array was reused.
        let b = forward_scalar_impl(&mut array, &mut sg, &input, &net, &weights);
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.stats, b.stats);
    }

    /// The bit-sliced conv equals the scalar oracle — output, wall clock,
    /// totals and the per-PE partition — on a padded geometry whose pixel
    /// count is not a multiple of 64.
    #[test]
    fn conv_sliced_matches_scalar() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (6, 6, 4), 3, 1, 1, 10, None);
        let input = BitTensor::random(6, 6, 4, 11);
        let weights = BinWeights::random(10, layer.fanin(), 5);
        let packed = crate::bnn::bitpack::PackedWeights::pack(&weights);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let scalar = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        let mut arr = SlicedArray::new(2, 4);
        let mut sg2 = SequenceGenerator::new();
        let sliced = conv_bin_sliced(&mut arr, &mut sg2, &input, &layer, &weights, &packed);
        assert_eq!(sliced.output, scalar.output);
        assert_eq!(sliced.cycles, scalar.cycles);
        assert_eq!(sliced.stats, scalar.stats);
        assert_eq!(arr.per_pe_stats(), array.per_pe_stats());
    }

    #[test]
    fn maxpool_sliced_matches_scalar() {
        let input = BitTensor::random(8, 8, 6, 21);
        for (k, stride) in [(2, 2), (3, 2)] {
            let mut array = small_array();
            let mut sg = SequenceGenerator::new();
            let scalar = maxpool_cycle(&mut array, &mut sg, &input, k, stride);
            let mut arr = SlicedArray::new(2, 4);
            let mut sg2 = SequenceGenerator::new();
            let sliced = maxpool_sliced(&mut arr, &mut sg2, &input, k, stride);
            assert_eq!(sliced.output, scalar.output, "k={k} stride={stride}");
            assert_eq!(sliced.cycles, scalar.cycles, "k={k} stride={stride}");
            assert_eq!(sliced.stats, scalar.stats, "k={k} stride={stride}");
            assert_eq!(arr.per_pe_stats(), array.per_pe_stats());
        }
    }

    /// FC equivalence including degenerate thresholds (always-true /
    /// always-false epilogues) and a z2 crossing the 64-lane boundary.
    #[test]
    fn fc_sliced_matches_scalar() {
        let layer = Layer::fc("f", LayerKind::FcBin, 64, 70);
        let mut weights = BinWeights::random(70, 64, 9);
        weights.thresholds[0] = -1; // epilogue degenerates to const-true
        weights.thresholds[69] = 64 + 5; // const-false
        let lanes = crate::bnn::bitpack::LaneWeights::pack(&weights);
        let input: Vec<bool> = (0..64).map(|i| i % 5 != 0).collect();
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let (sb, ss, sc) = fc_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        let mut arr = SlicedArray::new(2, 4);
        let mut sg2 = SequenceGenerator::new();
        let (lb, ls, lc) = fc_bin_sliced(&mut arr, &mut sg2, &input, &layer, &weights, &lanes);
        assert_eq!(lb, sb);
        assert_eq!(ls, ss);
        assert_eq!(lc, sc);
        assert_eq!(arr.stats(), array.stats());
        assert_eq!(arr.per_pe_stats(), array.per_pe_stats());
        assert!(lb[0] && !lb[69], "degenerate thresholds resolve as constants");
    }

    /// Whole-network equality: every field of the ForwardResult.
    #[test]
    fn forward_sliced_matches_scalar() {
        let net = tiny_bnn(8, 4, 3);
        let weights: Vec<BinWeights> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 90 + i as u64))
            .collect();
        let packed = SlicedWeights::pack(&net, &weights);
        let input = BitTensor::random(8, 8, 4, 17);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let a = forward_scalar_impl(&mut array, &mut sg, &input, &net, &weights);
        let mut arr = SlicedArray::new(2, 4);
        let mut sg2 = SequenceGenerator::new();
        let b = forward_sliced_impl(&mut arr, &mut sg2, &input, &net, &weights, &packed);
        assert_eq!(b.scores, a.scores);
        assert_eq!(b.cycles, a.cycles);
        assert_eq!(b.stats, a.stats);
        assert_eq!(b.layers, a.layers);
        assert_eq!(b.per_pe, a.per_pe);
    }

    /// Wall-clock cycles: PEs run the same program in lockstep, so batch
    /// cycles equal one node's cycles regardless of batch width (≤ array).
    #[test]
    fn lockstep_wall_clock() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (4, 4, 2), 3, 1, 1, 8, None);
        let input = BitTensor::random(4, 4, 2, 2);
        let weights = BinWeights::random(8, layer.fanin(), 2);
        let mut sg = SequenceGenerator::new();
        let mut array = small_array(); // 8 PEs → one batch
        let r = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        let node_cycles =
            sg.cycles(&OpDesc::ThresholdNode { n: 18, t_popcount: weights.thresholds[0] });
        assert_eq!(r.cycles, 16 * node_cycles);
    }
}

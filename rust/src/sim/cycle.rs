//! Bit-true, cycle-accurate execution of BNN layers on the TULIP-PE array.
//!
//! Every output activation is computed by streaming XNOR products through
//! the *actual control words* of the threshold-node schedule (Fig. 2b) on a
//! simulated PE — no arithmetic shortcuts — and cross-checked against the
//! functional reference in tests. This engine powers the end-to-end
//! example (`examples/e2e_inference.rs`) and the schedule-level unit tests;
//! full-size networks use the analytic model (`coordinator::exec`), whose
//! cycle counts this engine validates.

use crate::arch::unit::{xnor_products, xnor_products_into, PeArray};
use crate::bnn::tensor::{BinWeights, BitTensor};
use crate::bnn::{Layer, Network};
use crate::pe::PeStats;
use crate::scheduler::seqgen::{OpDesc, SequenceGenerator};

/// Result of a bit-true layer execution.
#[derive(Debug, Clone)]
pub struct CycleResult {
    /// The layer's output activation tensor.
    pub output: BitTensor,
    /// Aggregated PE activity.
    pub stats: PeStats,
    /// Wall-clock cycles (PEs run in lockstep; idle PEs are clock-gated).
    pub cycles: u64,
}

/// Per-layer observability record of a whole-network forward pass: where
/// the cycles and the PE activity went. Produced by [`forward_bin_cycle`];
/// the batched engine merges these across images. The records partition
/// the network exactly: `Σ layer.cycles == ForwardResult::cycles` and
/// `Σ layer.stats == ForwardResult::stats` (asserted by `tests/metrics.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerObs {
    /// Layer name from the network description.
    pub name: String,
    /// `"conv"`, `"conv+pool"` (fused max-pool folded into its conv
    /// layer's record) or `"fc"`.
    pub kind: &'static str,
    /// Lockstep wall-clock cycles spent in this layer.
    pub cycles: u64,
    /// PE activity delta attributable to this layer.
    pub stats: PeStats,
}

impl LayerObs {
    /// Accumulate another image's record for the same layer (the batched
    /// engine's per-layer aggregate).
    pub fn merge(&mut self, other: &LayerObs) {
        debug_assert_eq!(self.name, other.name, "merging records of different layers");
        self.cycles += other.cycles;
        self.stats.merge(&other.stats);
    }

    /// This layer's neuron utilization (see [`PeStats::utilization`]).
    pub fn utilization(&self) -> f64 {
        self.stats.utilization()
    }
}

/// Execute a binary conv layer bit-true on the PE array. One PE per OFM
/// channel; the window broadcast is shared (Fig. 6). Returns the
/// pre-pooling output map.
pub fn conv_bin_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    layer: &Layer,
    weights: &BinWeights,
) -> CycleResult {
    assert!(layer.is_binary() && layer.is_conv());
    assert_eq!(input.c, layer.z1);
    let (x2, y2) = layer.output_spatial();
    let mut out = BitTensor::zeros(y2, x2, layer.z2);
    let num_pes = array.num_pes();
    let mut wall_cycles = 0u64;
    let mut products: Vec<bool> = Vec::with_capacity(layer.fanin());
    let mut window: Vec<bool> = Vec::with_capacity(layer.fanin());

    for batch_base in (0..layer.z2).step_by(num_pes) {
        let batch = (layer.z2 - batch_base).min(num_pes);
        // Hoist the per-channel programs out of the pixel loop (§Perf):
        // the sequence generator broadcasts one control stream per node
        // descriptor, exactly as the hardware controller does.
        let progs: Vec<_> = (0..batch)
            .map(|i| {
                sg.program(&OpDesc::ThresholdNode {
                    n: layer.fanin(),
                    t_popcount: weights.thresholds[batch_base + i],
                })
            })
            .collect();
        for oy in 0..y2 {
            for ox in 0..x2 {
                input.window_into(oy, ox, layer.k, layer.stride, layer.padding, &mut window);
                let mut batch_cycles = 0u64;
                for (i, prog) in progs.iter().enumerate() {
                    let ch = batch_base + i;
                    xnor_products_into(&window, weights.filter(ch), &mut products);
                    let pe = array.pe_mut(i);
                    prog.schedule.run_on(pe, &products);
                    out.set(oy, ox, ch, pe.neuron_out(prog.out_neuron.unwrap()));
                    batch_cycles = batch_cycles.max(prog.schedule.cycles() as u64);
                }
                wall_cycles += batch_cycles;
            }
        }
    }
    CycleResult { output: out, stats: array.stats(), cycles: wall_cycles }
}

/// Bit-true max-pooling on the PEs (OR schedule, Fig. 5b).
pub fn maxpool_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    k: usize,
    stride: usize,
) -> CycleResult {
    let oh = (input.h - k) / stride + 1;
    let ow = (input.w - k) / stride + 1;
    let mut out = BitTensor::zeros(oh, ow, input.c);
    let prog = sg.program(&OpDesc::Maxpool { n: k * k });
    let num_pes = array.num_pes();
    let mut wall_cycles = 0u64;
    for ch_base in (0..input.c).step_by(num_pes) {
        let batch = (input.c - ch_base).min(num_pes);
        for oy in 0..oh {
            for ox in 0..ow {
                for i in 0..batch {
                    let ch = ch_base + i;
                    let mut window = Vec::with_capacity(k * k);
                    for ky in 0..k {
                        for kx in 0..k {
                            window.push(input.get(oy * stride + ky, ox * stride + kx, ch));
                        }
                    }
                    let pe = array.pe_mut(i);
                    prog.schedule.run_on(pe, &window);
                    out.set(oy, ox, ch, pe.neuron_out(prog.out_neuron.unwrap()));
                }
                wall_cycles += prog.schedule.cycles() as u64;
            }
        }
    }
    CycleResult { output: out, stats: array.stats(), cycles: wall_cycles }
}

/// Bit-true binary FC layer: one PE per output neuron, batched over the
/// array. Returns the binarized outputs; `scores` additionally recovers the
/// raw popcount from the PE register file (used by the classifier head).
pub fn fc_bin_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &[bool],
    layer: &Layer,
    weights: &BinWeights,
) -> (Vec<bool>, Vec<i64>, u64) {
    assert!(layer.is_fc());
    assert_eq!(input.len(), layer.z1);
    let num_pes = array.num_pes();
    let mut bits = vec![false; layer.z2];
    let mut scores = vec![0i64; layer.z2];
    let mut wall_cycles = 0u64;
    for batch_base in (0..layer.z2).step_by(num_pes) {
        let batch = (layer.z2 - batch_base).min(num_pes);
        let mut batch_cycles = 0u64;
        for i in 0..batch {
            let ch = batch_base + i;
            let prog = sg.program(&OpDesc::ThresholdNode {
                n: layer.z1,
                t_popcount: weights.thresholds[ch],
            });
            let products = xnor_products(input, weights.filter(ch));
            let pe = array.pe_mut(i);
            prog.schedule.run_on(pe, &products);
            bits[ch] = pe.neuron_out(prog.out_neuron.unwrap());
            // The raw sum remains in the register file at `out_loc` — read
            // it back for the classifier head.
            if let Some(crate::scheduler::Loc::Reg { reg, lsb, width }) = prog.out_loc {
                scores[ch] = pe.regs().peek_field(reg, lsb, width) as i64;
            }
            batch_cycles = batch_cycles.max(prog.schedule.cycles() as u64);
        }
        wall_cycles += batch_cycles;
    }
    (bits, scores, wall_cycles)
}

/// Result of a whole-network bit-true forward pass.
#[derive(Debug, Clone)]
pub struct ForwardResult {
    /// Raw final-layer popcount scores (argmax = predicted class).
    pub scores: Vec<i64>,
    /// Chip cycles summed over every layer (lockstep wall clock).
    pub cycles: u64,
    /// PE activity for this image alone — the array's counters are reset on
    /// entry, so consecutive calls yield independently summable records.
    pub stats: PeStats,
    /// Per-layer breakdown: partitions `cycles` and `stats` exactly.
    pub layers: Vec<LayerObs>,
    /// Per-PE activity in array-flattened index order (same indexing as
    /// [`PeArray::pe_mut`]) — the source for per-PE utilization reports.
    pub per_pe: Vec<PeStats>,
}

/// Run a whole **binary** network bit-true on the PE array: conv layers
/// (with their fused max-pool) then the FC stack, returning the raw scores
/// of the final layer. This is the per-image unit of work of the batched
/// inference engine (`coordinator::batch`); integer layers are out of scope
/// here exactly as they are for the TULIP-PEs (§V-C routes them to MACs).
pub fn forward_bin_cycle(
    array: &mut PeArray,
    sg: &mut SequenceGenerator,
    input: &BitTensor,
    net: &Network,
    weights: &[BinWeights],
) -> ForwardResult {
    assert_eq!(net.layers.len(), weights.len(), "one weight set per layer");
    array.reset_stats();
    let mut cycles = 0u64;
    let mut layers: Vec<LayerObs> = Vec::with_capacity(net.layers.len());
    let mut act = input.clone();
    let mut flat: Option<Vec<bool>> = None;
    for (i, (layer, w)) in net.layers.iter().zip(weights).enumerate() {
        let last = i + 1 == net.layers.len();
        let stats_before = array.stats();
        let cycles_before = cycles;
        if layer.is_conv() {
            assert!(layer.is_binary(), "forward_bin_cycle handles binary networks only");
            assert!(
                flat.is_none(),
                "conv layer '{}' cannot follow an FC layer (chain topology, §I)",
                layer.name
            );
            let r = conv_bin_cycle(array, sg, &act, layer, w);
            cycles += r.cycles;
            act = r.output;
            let kind = if layer.pool.is_some() { "conv+pool" } else { "conv" };
            if let Some((pk, ps)) = layer.pool {
                let p = maxpool_cycle(array, sg, &act, pk, ps);
                cycles += p.cycles;
                act = p.output;
            }
            layers.push(LayerObs {
                name: layer.name.clone(),
                kind,
                cycles: cycles - cycles_before,
                stats: array.stats().delta(&stats_before),
            });
        } else {
            assert!(layer.is_binary(), "forward_bin_cycle handles binary networks only");
            let input_flat = flat.take().unwrap_or_else(|| act.flatten());
            let (bits, scores, fc_cycles) = fc_bin_cycle(array, sg, &input_flat, layer, w);
            cycles += fc_cycles;
            layers.push(LayerObs {
                name: layer.name.clone(),
                kind: "fc",
                cycles: cycles - cycles_before,
                stats: array.stats().delta(&stats_before),
            });
            if last {
                return ForwardResult {
                    scores,
                    cycles,
                    stats: array.stats(),
                    layers,
                    per_pe: array.per_pe_stats(),
                };
            }
            flat = Some(bits);
        }
    }
    panic!("network must end in an FC layer");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::layer::LayerKind;
    use crate::bnn::reference;
    use crate::bnn::tiny_bnn;

    fn small_array() -> PeArray {
        PeArray::new(2, 4) // 8 PEs keeps tests fast
    }

    /// Bit-true conv equals the functional reference on random tensors.
    #[test]
    fn conv_bit_true_matches_reference() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (6, 6, 4), 3, 1, 1, 10, None);
        let input = BitTensor::random(6, 6, 4, 11);
        let weights = BinWeights::random(10, layer.fanin(), 5);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let got = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        let expect = reference::conv_bin(&input, &layer, &weights);
        assert_eq!(got.output, expect);
        assert!(got.cycles > 0 && got.stats.neuron_evals > 0);
    }

    /// Stride-2, no-padding geometry also matches.
    #[test]
    fn conv_strided_matches_reference() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (8, 8, 2), 3, 2, 0, 3, None);
        let input = BitTensor::random(8, 8, 2, 3);
        let weights = BinWeights::random(3, layer.fanin(), 8);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let got = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        assert_eq!(got.output, reference::conv_bin(&input, &layer, &weights));
    }

    #[test]
    fn maxpool_bit_true_matches_reference() {
        let input = BitTensor::random(8, 8, 6, 21);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let got = maxpool_cycle(&mut array, &mut sg, &input, 2, 2);
        assert_eq!(got.output, reference::maxpool(&input, 2, 2));
        // AlexNet-style 3×3/2 overlapping pool too.
        let got3 = maxpool_cycle(&mut array, &mut sg, &input, 3, 2);
        assert_eq!(got3.output, reference::maxpool(&input, 3, 2));
    }

    #[test]
    fn fc_bit_true_matches_reference() {
        let layer = Layer::fc("f", LayerKind::FcBin, 64, 12);
        let weights = BinWeights::random(12, 64, 9);
        let input: Vec<bool> = (0..64).map(|i| i % 5 != 0).collect();
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let (bits, scores, cycles) = fc_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        assert_eq!(bits, reference::fc_bin(&input, &layer, &weights));
        assert_eq!(scores, reference::fc_scores(&input, &layer, &weights));
        assert!(cycles > 0);
    }

    /// The whole-network forward pass equals the functional reference and
    /// resets its activity accounting per call.
    #[test]
    fn forward_bin_matches_reference() {
        let net = tiny_bnn(8, 4, 3);
        let weights: Vec<BinWeights> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), 90 + i as u64))
            .collect();
        let input = BitTensor::random(8, 8, 4, 17);
        let mut array = small_array();
        let mut sg = SequenceGenerator::new();
        let a = forward_bin_cycle(&mut array, &mut sg, &input, &net, &weights);
        assert_eq!(a.scores, reference::forward_scores(&net, &input, &weights));
        assert!(a.cycles > 0 && a.stats.neuron_evals > 0);
        // Per-image accounting: a second identical pass reports identical
        // (not accumulated) stats, even though the array was reused.
        let b = forward_bin_cycle(&mut array, &mut sg, &input, &net, &weights);
        assert_eq!(a.scores, b.scores);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.stats, b.stats);
    }

    /// Wall-clock cycles: PEs run the same program in lockstep, so batch
    /// cycles equal one node's cycles regardless of batch width (≤ array).
    #[test]
    fn lockstep_wall_clock() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (4, 4, 2), 3, 1, 1, 8, None);
        let input = BitTensor::random(4, 4, 2, 2);
        let weights = BinWeights::random(8, layer.fanin(), 2);
        let mut sg = SequenceGenerator::new();
        let mut array = small_array(); // 8 PEs → one batch
        let r = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
        let node_cycles =
            sg.cycles(&OpDesc::ThresholdNode { n: 18, t_popcount: weights.thresholds[0] });
        assert_eq!(r.cycles, 16 * node_cycles);
    }
}

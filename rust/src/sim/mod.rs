//! Simulation engines.
//!
//! * [`cycle`] — **bit-true, cycle-accurate**: every output bit is produced
//!   by stepping TULIP-PEs through real control words. Used for
//!   correctness (vs the rust functional reference and the JAX golden
//!   model) and for validating the analytic model.
//! * [`perf`] — consistency layer: asserts that the analytic cycle/energy
//!   counts used by the coordinator equal what bit-true execution measures
//!   on sampled workloads (the two are built from the same `Schedule`
//!   objects, so this pins the construction).

pub mod cycle;
pub mod perf;
pub mod trace;

//! Architecture configuration — the knobs §IV-E says can be "tailored for a
//! given application" (PE/MAC counts, on-chip IFM capacity, interface
//! widths). Defaults reproduce the paper's evaluated design point; the
//! ablation benches sweep them.

use crate::energy::calib;

/// Which design point a simulation models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchKind {
    /// TULIP: 256 TULIP-PEs for binary layers + 32 simplified MACs for
    /// integer layers.
    Tulip,
    /// YodaNN [17]: 32 fully reconfigurable MACs for every layer.
    Yodann,
}

impl std::fmt::Display for ArchKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArchKind::Tulip => write!(f, "TULIP"),
            ArchKind::Yodann => write!(f, "YodaNN"),
        }
    }
}

/// Tunable architecture parameters.
#[derive(Debug, Clone)]
pub struct ArchConfig {
    /// Which design point this configuration models.
    pub kind: ArchKind,
    /// Number of TULIP-PEs (binary-layer OFM parallelism).
    pub num_pes: usize,
    /// Number of MAC units (integer layers; all layers for YodaNN).
    pub num_macs: usize,
    /// IFMs resident on-chip per slab (§V-C: 32; doubled for k ≤ 5 on the
    /// MAC path).
    pub onchip_ifms: usize,
    /// Off-chip interface bandwidth, bits/cycle.
    pub offchip_bits_per_cycle: f64,
    /// FC weight-stream bandwidth, bits/cycle.
    pub weight_bits_per_cycle: f64,
    /// Maximum fan-in a single PE adder-tree pass handles before the
    /// coordinator switches to chunk + accumulate (§IV-C: "up to 10-bit
    /// addition", i.e. 1023 inputs).
    pub max_tree_fanin: usize,
}

impl ArchConfig {
    /// The paper's TULIP design point.
    pub fn tulip() -> Self {
        ArchConfig {
            kind: ArchKind::Tulip,
            num_pes: calib::TULIP_NUM_PES,
            num_macs: calib::NUM_MACS,
            onchip_ifms: calib::ONCHIP_IFMS,
            offchip_bits_per_cycle: calib::OFFCHIP_BITS_PER_CYCLE,
            weight_bits_per_cycle: calib::WEIGHT_BITS_PER_CYCLE,
            max_tree_fanin: 1023,
        }
    }

    /// The paper's YodaNN comparison point (same buffers, 32 full MACs).
    pub fn yodann() -> Self {
        ArchConfig { kind: ArchKind::Yodann, num_pes: 0, ..Self::tulip() }
    }

    /// Scale the processing array (the paper's scalability claim: "the
    /// throughput can simply be increased linearly by adding PEs").
    pub fn with_pes(mut self, pes: usize) -> Self {
        self.num_pes = pes;
        self
    }

    /// Override the off-chip interface bandwidth (ablation sweeps).
    pub fn with_offchip_bw(mut self, bits_per_cycle: f64) -> Self {
        self.offchip_bits_per_cycle = bits_per_cycle;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_points() {
        let t = ArchConfig::tulip();
        assert_eq!((t.num_pes, t.num_macs, t.onchip_ifms), (256, 32, 32));
        let y = ArchConfig::yodann();
        assert_eq!(y.num_pes, 0);
        assert_eq!(y.num_macs, 32);
        assert_eq!(format!("{}/{}", t.kind, y.kind), "TULIP/YodaNN");
    }

    #[test]
    fn builders() {
        let t = ArchConfig::tulip().with_pes(512).with_offchip_bw(4.0);
        assert_eq!(t.num_pes, 512);
        assert_eq!(t.offchip_bits_per_cycle, 4.0);
    }
}

//! Arbitrary threshold functions `(W, T) = [w_1..w_n; T]` (Eq. 1) and the
//! checks the decomposition pipeline needs: evaluation, boundedness, and
//! the reduction of a BNN node (±1 weights) to a popcount-vs-threshold test.


/// A threshold function `f(x) = 1 ⇔ Σ w_i x_i ≥ T` with integer weights
/// (W.l.o.g. integer weights/threshold suffice — Muroga '71, paper fn. 1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ThresholdFunction {
    /// Integer weights `w_1..w_n`.
    pub weights: Vec<i32>,
    /// Threshold `T`.
    pub threshold: i32,
}

impl ThresholdFunction {
    /// Build `[w_1..w_n; T]`.
    pub fn new(weights: Vec<i32>, threshold: i32) -> Self {
        Self { weights, threshold }
    }

    /// The TULIP cell: `[2,1,1,1;T]`.
    pub fn tulip_cell(threshold: i32) -> Self {
        Self::new(vec![2, 1, 1, 1], threshold)
    }

    /// Fan-in of the function.
    pub fn fanin(&self) -> usize {
        self.weights.len()
    }

    /// Evaluate on a Boolean input vector (length must equal fan-in).
    pub fn eval(&self, x: &[bool]) -> bool {
        assert_eq!(x.len(), self.weights.len(), "fan-in mismatch");
        self.weighted_sum(x) >= self.threshold
    }

    /// The LHS of Eq. 1, `Σ w_i x_i`.
    pub fn weighted_sum(&self, x: &[bool]) -> i32 {
        self.weights.iter().zip(x).map(|(w, &xi)| w * xi as i32).sum()
    }

    /// A BNN node: ±1 weights over `n` binarized activations, threshold `t`.
    ///
    /// With activations encoded `{0,1}` and products formed by XNOR, the
    /// weighted sum becomes `2·popcount(xnor(x,w)) − n`, so the node is the
    /// threshold test `popcount ≥ ⌈(t + n)/2⌉` — this is the reduction the
    /// adder-tree scheduler implements (§III).
    pub fn bnn_node(signed_weights: &[i8], threshold: i32) -> Self {
        Self::new(signed_weights.iter().map(|&w| w as i32).collect(), threshold)
    }

    /// Popcount threshold equivalent for a ±1-weight node (see
    /// [`ThresholdFunction::bnn_node`]): returns `T'` such that
    /// `f(x) = popcount(xnor) ≥ T'`.
    pub fn popcount_threshold(&self) -> i32 {
        let n = self.weights.len() as i32;
        // Σ±1·(2x−1)... derivation: with w ∈ {±1}, x ∈ {0,1},
        // Σ w_i (2x_i − 1) over the ±1-activation view equals
        // 2·popcount(xnor) − n; f ⇔ 2·pc − n ≥ T ⇔ pc ≥ ⌈(T+n)/2⌉.
        (self.threshold + n + 1).div_euclid(2)
    }

    /// True when all weights are ±1 (a binary-layer node).
    pub fn is_binary(&self) -> bool {
        self.weights.iter().all(|&w| w == 1 || w == -1)
    }
}

/// Popcount of XNOR(x, w) for a ±1-weight node over {0,1} activations —
/// the quantity the adder tree accumulates.
pub fn xnor_popcount(x: &[bool], w: &[i8]) -> u32 {
    assert_eq!(x.len(), w.len());
    x.iter()
        .zip(w)
        .map(|(&xi, &wi)| {
            let wb = wi > 0; // +1 ↦ 1, −1 ↦ 0
            (xi == wb) as u32
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_matches_definition() {
        let f = ThresholdFunction::new(vec![2, 1, 1, 1], 3);
        assert!(f.eval(&[true, true, false, false])); // 2+1 ≥ 3
        assert!(!f.eval(&[false, true, true, false])); // 1+1 < 3
        assert_eq!(f.fanin(), 4);
    }

    #[test]
    fn popcount_threshold_equivalence_exhaustive() {
        // For every small ±1-weight node, the popcount formulation must agree
        // with the signed-sum formulation on every input.
        let weights: [i8; 5] = [1, -1, 1, 1, -1];
        for t in -6..=6 {
            let f = ThresholdFunction::bnn_node(&weights, t);
            let tp = f.popcount_threshold();
            for m in 0u32..32 {
                let x: Vec<bool> = (0..5).map(|i| m >> i & 1 != 0).collect();
                // signed view: activations ±1
                let signed: i32 = weights
                    .iter()
                    .zip(&x)
                    .map(|(&w, &xi)| w as i32 * if xi { 1 } else { -1 })
                    .sum();
                let via_pc = xnor_popcount(&x, &weights) as i32 >= tp;
                assert_eq!(signed >= t, via_pc, "t={t} m={m:05b}");
            }
        }
    }

    #[test]
    fn xnor_popcount_basics() {
        assert_eq!(xnor_popcount(&[true, false], &[1, -1]), 2);
        assert_eq!(xnor_popcount(&[false, true], &[1, -1]), 0);
    }

    #[test]
    fn binary_detection() {
        assert!(ThresholdFunction::bnn_node(&[1, -1, 1], 0).is_binary());
        assert!(!ThresholdFunction::tulip_cell(2).is_binary());
    }
}

//! The binary neuron — a programmable threshold-logic standard cell.
//!
//! Section II of the paper: a Boolean function `f` is a *threshold function*
//! if there are weights `w_i` and a threshold `T` such that
//! `f(x) = 1 ⇔ Σ w_i·x_i ≥ T` (Eq. 1). The mixed-signal cell of [21]
//! realizes one such function as a single edge-triggered standard cell
//! (LIN/RIN differential networks + sense amp + latch, Fig. 1).
//!
//! TULIP programs every cell to the weight vector **[2, 1, 1, 1; T]** over
//! inputs `(a, b, c, d)` and switches `T` at run time through digital
//! control signals. This module models:
//!
//! * the mathematical object ([`ThresholdFunction`]) and its evaluation,
//! * the physical cell ([`HwNeuron`]): the `[2,1,1,1;T]` gate with an
//!   edge-triggered output latch and a clock-gate, exactly the contract the
//!   TULIP-PE scheduler relies on,
//! * the cell's measured characteristics across corners
//!   ([`characteristics`], Table I), which feed the energy model.

pub mod characteristics;
pub mod function;

pub use characteristics::{
    table1_improvements, CellCharacteristics, Corner, CMOS_EQUIVALENT, HW_NEURON,
};
pub use function::ThresholdFunction;

/// The programmable threshold-logic cell used by every TULIP-PE neuron:
/// weights fixed at `[2, 1, 1, 1]` over `(a, b, c, d)`, threshold `T`
/// switched at run time by control signals, output held in an edge-triggered
/// latch (Fig. 1 / Fig. 3 of the paper).
///
/// The latch state persists across cycles when the cell is clock-gated or
/// when the sense amplifier outputs are equal — which is exactly how the
/// sequential comparator schedule (Fig. 5a) keeps its running verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HwNeuron {
    /// Latched output of the most recent evaluation.
    state: bool,
    /// Number of evaluations performed (→ dynamic-energy accounting).
    evals: u64,
}

/// Weight of input `a` in the `[2,1,1,1;T]` cell.
pub const WEIGHT_A: i32 = 2;
/// Weight of inputs `b`, `c`, `d`.
pub const WEIGHT_BCD: i32 = 1;
/// Maximum achievable weighted sum for the `[2,1,1,1;T]` cell.
pub const MAX_SUM: i32 = 5;

impl HwNeuron {
    /// A quiescent cell with the latch reset.
    pub fn new() -> Self {
        Self { state: false, evals: 0 }
    }

    /// Latched output (valid between clock edges).
    #[inline]
    pub fn output(&self) -> bool {
        self.state
    }

    /// Force the latch to a known state (used by schedule preambles; the
    /// hardware does this by evaluating with `T = 0` or `T = MAX_SUM + 1`).
    #[inline]
    pub fn set(&mut self, v: bool) {
        self.state = v;
    }

    /// One clock edge: evaluate `2a + b + c + d ≥ t` and latch the result.
    ///
    /// `t` is the run-time programmed threshold. `t ≤ 0` latches 1
    /// unconditionally, `t > MAX_SUM` latches 0 — both are used by the
    /// scheduler to initialize latches.
    #[inline]
    pub fn clock(&mut self, a: bool, b: bool, c: bool, d: bool, t: i32) -> bool {
        let sum = WEIGHT_A * a as i32
            + WEIGHT_BCD * b as i32
            + WEIGHT_BCD * c as i32
            + WEIGHT_BCD * d as i32;
        self.state = sum >= t;
        self.evals += 1;
        self.state
    }

    /// Dynamic-evaluation count for the energy model.
    #[inline]
    pub fn eval_count(&self) -> u64 {
        self.evals
    }

    /// Reset the energy counter (e.g. between benchmark sections).
    pub fn reset_counters(&mut self) {
        self.evals = 0;
    }
}

impl Default for HwNeuron {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exhaustive truth-table check of the [2,1,1,1;T] cell against Eq. 1
    /// for every input minterm and every meaningful threshold.
    #[test]
    fn cell_matches_eq1_exhaustively() {
        for t in -1..=6 {
            for m in 0u32..16 {
                let (a, b, c, d) = (m & 1 != 0, m & 2 != 0, m & 4 != 0, m & 8 != 0);
                let mut n = HwNeuron::new();
                let got = n.clock(a, b, c, d, t);
                let sum = 2 * a as i32 + b as i32 + c as i32 + d as i32;
                assert_eq!(got, sum >= t, "minterm {m:04b} T={t}");
                assert_eq!(n.output(), got);
            }
        }
    }

    /// The paper's running example: f = a·d ∨ b·c·d = [2,1,1,1;4]... the
    /// paper's §II example is [2,1,1,1;3] realizing ad ∨ bcd. Verify it.
    #[test]
    fn paper_example_ad_or_bcd() {
        // [w_a,w_b,w_c,w_d;T] = [2,1,1,1;3] realizes f = ad ∨ bc d? The
        // paper states f = ad ∨ bcd. Check the identity for all minterms.
        for m in 0u32..16 {
            let (a, b, c, d) = (m & 1 != 0, m & 2 != 0, m & 4 != 0, m & 8 != 0);
            let mut n = HwNeuron::new();
            let got = n.clock(a, b, c, d, 3);
            let expect = (a && d) || (b && c && d) || (a && b && c);
            // 2a+b+c+d >= 3 is satisfied by {a,d},{a,b},{a,c},{b,c,d},...
            // i.e. f = a(b∨c∨d) ∨ bcd. The paper's compact form lists the
            // prime implicants ad ∨ bcd for the subfunction with b=c; the
            // full expansion is a(b∨c∨d) ∨ bcd:
            let full = (a && (b || c || d)) || (b && c && d);
            assert_eq!(got, full, "minterm {m:04b}");
            let _ = expect; // documented alternative factoring
        }
    }

    /// T outside [0, MAX_SUM] pins the latch — scheduler preamble contract.
    #[test]
    fn threshold_extremes_pin_latch() {
        let mut n = HwNeuron::new();
        assert!(n.clock(false, false, false, false, 0));
        assert!(!n.clock(true, true, true, true, MAX_SUM + 1));
    }

    /// The latch holds state: `output` is stable without a clock edge.
    #[test]
    fn latch_holds_between_edges() {
        let mut n = HwNeuron::new();
        n.clock(true, false, false, false, 2);
        assert!(n.output());
        assert!(n.output()); // no edge, no change
        assert_eq!(n.eval_count(), 1);
    }

    /// Energy counter increments once per edge.
    #[test]
    fn eval_counter_counts_edges() {
        let mut n = HwNeuron::new();
        for _ in 0..17 {
            n.clock(true, true, false, false, 3);
        }
        assert_eq!(n.eval_count(), 17);
        n.reset_counters();
        assert_eq!(n.eval_count(), 0);
    }
}

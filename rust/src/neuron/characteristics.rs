//! Measured cell characteristics (Table I of the paper) and PVT corners.
//!
//! These constants are *calibration inputs* to the analytical model: the
//! paper characterized the re-implemented 40nm hardware neuron of [21]
//! programmed to `[2,1,1,1;T]` across SS/TT/FF corners, and reports the
//! TT-corner area/power/delay against a conventional CMOS standard-cell
//! equivalent of the same logic (Table I).


/// Process/voltage/temperature corner used for characterization (§V-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Corner {
    /// Slow-slow, 0.81 V, 125 °C.
    SS,
    /// Typical-typical, 0.9 V, 25 °C — the corner every table reports.
    TT,
    /// Fast-fast, 0.99 V, 0 °C.
    FF,
}

impl Corner {
    /// Supply voltage at this corner (V).
    pub fn vdd(self) -> f64 {
        match self {
            Corner::SS => 0.81,
            Corner::TT => 0.90,
            Corner::FF => 0.99,
        }
    }

    /// Junction temperature at this corner (°C).
    pub fn temperature(self) -> f64 {
        match self {
            Corner::SS => 125.0,
            Corner::TT => 25.0,
            Corner::FF => 0.0,
        }
    }

    /// First-order derating of delay relative to TT. Mixed-signal threshold
    /// cells slow down at low VDD roughly with the alpha-power law; we use
    /// the conventional (VDD/VDD_TT)^-1.6 fit, which reproduces the usual
    /// ±25-30% SS/FF swing of 40nm-LP libraries.
    pub fn delay_derate(self) -> f64 {
        (self.vdd() / Corner::TT.vdd()).powf(-1.6)
    }

    /// First-order dynamic-power derating relative to TT: P ∝ VDD².
    pub fn power_derate(self) -> f64 {
        (self.vdd() / Corner::TT.vdd()).powi(2)
    }

    /// Every corner, slow to fast.
    pub const ALL: [Corner; 3] = [Corner::SS, Corner::TT, Corner::FF];
}

impl std::fmt::Display for Corner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Corner::SS => "SS 0.81V 125C",
            Corner::TT => "TT 0.90V 25C",
            Corner::FF => "FF 0.99V 0C",
        };
        write!(f, "{s}")
    }
}

/// Area / power / delay of a standard cell at the TT corner.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellCharacteristics {
    /// Cell area, µm².
    pub area_um2: f64,
    /// Average power while clocked, µW.
    pub power_uw: f64,
    /// Worst-case clock-to-q delay, ps.
    pub worst_delay_ps: f64,
}

impl CellCharacteristics {
    /// Characteristics derated to a given corner (TT values are measured;
    /// SS/FF are first-order derated — the paper characterized all three
    /// corners but reports only TT numbers).
    pub fn at_corner(&self, corner: Corner) -> CellCharacteristics {
        CellCharacteristics {
            area_um2: self.area_um2, // area is corner-independent
            power_uw: self.power_uw * corner.power_derate(),
            worst_delay_ps: self.worst_delay_ps * corner.delay_derate(),
        }
    }

    /// Energy per clocked evaluation at a given clock period (fJ):
    /// µW × ns = 10⁻⁶ W × 10⁻⁹ s = fJ.
    pub fn energy_per_cycle_fj(&self, period_ns: f64) -> f64 {
        self.power_uw * period_ns
    }
}

/// Table I, column "Hardware Neuron [21]": the mixed-signal threshold cell.
pub const HW_NEURON: CellCharacteristics =
    CellCharacteristics { area_um2: 15.6, power_uw: 4.46, worst_delay_ps: 384.0 };

/// Table I, column "Logical Equivalent": conventional CMOS standard cells
/// implementing the same `[2,1,1,1;T]` function + flip-flop.
pub const CMOS_EQUIVALENT: CellCharacteristics =
    CellCharacteristics { area_um2: 27.0, power_uw: 6.72, worst_delay_ps: 697.0 };

/// Improvement factors reported in Table I (X column).
pub fn table1_improvements() -> (f64, f64, f64) {
    (
        CMOS_EQUIVALENT.area_um2 / HW_NEURON.area_um2,
        CMOS_EQUIVALENT.power_uw / HW_NEURON.power_uw,
        CMOS_EQUIVALENT.worst_delay_ps / HW_NEURON.worst_delay_ps,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I's X column: 1.8X area, 1.5X power, 1.8X delay.
    #[test]
    fn table1_ratios_match_paper() {
        let (a, p, d) = table1_improvements();
        assert!((a - 1.73).abs() < 0.1, "area ratio {a}");
        assert!((p - 1.51).abs() < 0.05, "power ratio {p}");
        assert!((d - 1.81).abs() < 0.05, "delay ratio {d}");
    }

    #[test]
    fn corner_derating_is_monotone() {
        let ss = HW_NEURON.at_corner(Corner::SS);
        let tt = HW_NEURON.at_corner(Corner::TT);
        let ff = HW_NEURON.at_corner(Corner::FF);
        assert!(ss.worst_delay_ps > tt.worst_delay_ps);
        assert!(tt.worst_delay_ps > ff.worst_delay_ps);
        assert!(ss.power_uw < tt.power_uw);
        assert!(tt.power_uw < ff.power_uw);
        assert_eq!(ss.area_um2, tt.area_um2);
    }

    #[test]
    fn tt_corner_is_identity() {
        let tt = HW_NEURON.at_corner(Corner::TT);
        assert!((tt.power_uw - HW_NEURON.power_uw).abs() < 1e-12);
        assert!((tt.worst_delay_ps - HW_NEURON.worst_delay_ps).abs() < 1e-12);
    }

    /// The cell's worst delay must fit in the 2.3 ns clock the paper uses
    /// even at the SS corner — otherwise Table II's timing is impossible.
    #[test]
    fn cell_fits_clock_at_all_corners() {
        for c in Corner::ALL {
            assert!(HW_NEURON.at_corner(c).worst_delay_ps < 2300.0 / 2.0);
        }
    }

    #[test]
    fn energy_per_cycle() {
        // 4.46 µW × 2.3 ns ≈ 10.26 fJ
        let e = HW_NEURON.energy_per_cycle_fj(2.3);
        assert!((e - 10.258).abs() < 1e-2, "{e}");
    }
}

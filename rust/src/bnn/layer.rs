//! A single BNN layer and the paper's operation-count formulas (§V-C).


/// Layer kind. The paper's workloads have integer first layers ("In large
/// BNN architectures such as Alexnet, the initial layers are integer
/// layers, while the rest of the layers are binary") and binary everything
/// else; max-pooling and batch-norm are folded into the conv layers as in
/// the paper's schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Convolution with integer activations (up to 12 bits), binary weights.
    ConvInt,
    /// Convolution with binary activations and weights.
    ConvBin,
    /// Fully connected, integer activations, binary weights.
    FcInt,
    /// Fully connected, binary activations and weights.
    FcBin,
}

/// One layer. Notation follows §V-C: IFMs `(x1, y1, z1)`, OFMs
/// `(x2, y2, z2)`, kernel `k × k`.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Layer name (paper row label, e.g. "conv1").
    pub name: String,
    /// Layer kind (conv/FC × integer/binary).
    pub kind: LayerKind,
    /// IFM width.
    pub x1: usize,
    /// IFM height.
    pub y1: usize,
    /// IFM channels (for FC layers: the flattened input length).
    pub z1: usize,
    /// Kernel size (1 for FC).
    pub k: usize,
    /// Convolution stride.
    pub stride: usize,
    /// Zero padding on each edge.
    pub padding: usize,
    /// OFM channels (FC: output length).
    pub z2: usize,
    /// Max-pooling window/stride applied after the layer, if any.
    pub pool: Option<(usize, usize)>,
    /// Activation bits (12 for integer layers, 1 for binary).
    pub input_bits: u32,
    /// §V-C, Table III: AlexNet's first layer is processed in 4 image
    /// parts because the full frame does not fit on-chip.
    pub image_parts: usize,
}

impl Layer {
    /// Convolution layer constructor.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: &str,
        kind: LayerKind,
        (x1, y1, z1): (usize, usize, usize),
        k: usize,
        stride: usize,
        padding: usize,
        z2: usize,
        pool: Option<(usize, usize)>,
    ) -> Self {
        assert!(matches!(kind, LayerKind::ConvInt | LayerKind::ConvBin));
        Layer {
            name: name.into(),
            kind,
            x1,
            y1,
            z1,
            k,
            stride,
            padding,
            z2,
            pool,
            input_bits: if kind == LayerKind::ConvInt { 12 } else { 1 },
            image_parts: 1,
        }
    }

    /// Fully connected layer constructor.
    pub fn fc(name: &str, kind: LayerKind, z1: usize, z2: usize) -> Self {
        assert!(matches!(kind, LayerKind::FcInt | LayerKind::FcBin));
        Layer {
            name: name.into(),
            kind,
            x1: 1,
            y1: 1,
            z1,
            k: 1,
            stride: 1,
            padding: 0,
            z2,
            pool: None,
            input_bits: if kind == LayerKind::FcInt { 12 } else { 1 },
            image_parts: 1,
        }
    }

    /// Set the image-part count (§V-C, Table III).
    pub fn with_parts(mut self, parts: usize) -> Self {
        self.image_parts = parts;
        self
    }

    /// Is this a convolution layer?
    pub fn is_conv(&self) -> bool {
        matches!(self.kind, LayerKind::ConvInt | LayerKind::ConvBin)
    }

    /// Is this a fully connected layer?
    pub fn is_fc(&self) -> bool {
        !self.is_conv()
    }

    /// Does the layer run on the binary datapath?
    pub fn is_binary(&self) -> bool {
        matches!(self.kind, LayerKind::ConvBin | LayerKind::FcBin)
    }

    /// OFM spatial dims `(x2, y2)` before pooling.
    pub fn output_spatial(&self) -> (usize, usize) {
        let x2 = (self.x1 + 2 * self.padding - self.k) / self.stride + 1;
        let y2 = (self.y1 + 2 * self.padding - self.k) / self.stride + 1;
        (x2, y2)
    }

    /// Output dims `(x, y, z)` after the fused pooling step.
    pub fn output_dims_after_pool(&self) -> (usize, usize, usize) {
        let (mut x2, mut y2) = self.output_spatial();
        if let Some((pk, ps)) = self.pool {
            x2 = (x2 - pk) / ps + 1;
            y2 = (y2 - pk) / ps + 1;
        }
        (x2, y2, self.z2)
    }

    /// Fan-in of one output neuron: `z1 · k²`.
    pub fn fanin(&self) -> usize {
        self.z1 * self.k * self.k
    }

    /// Number of output pixels `x2 · y2` (1 for FC).
    pub fn output_pixels(&self) -> usize {
        let (x2, y2) = self.output_spatial();
        x2 * y2
    }

    /// Operation count per the paper (§V-C): `2·z1·k²·x2·y2·z2` MAC
    /// operations plus `x2·y2·z2` threshold comparisons.
    pub fn ops(&self) -> u64 {
        let (x2, y2) = self.output_spatial();
        let mac = 2 * self.z1 as u64
            * (self.k * self.k) as u64
            * (x2 * y2) as u64
            * self.z2 as u64;
        let cmp = (x2 * y2) as u64 * self.z2 as u64;
        mac + cmp
    }

    /// Total weight bits the kernel buffer must hold / stream for this
    /// layer (binary weights throughout, §V-A).
    pub fn weight_bits(&self) -> u64 {
        (self.z1 * self.k * self.k * self.z2) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        let l = Layer::conv("c", LayerKind::ConvBin, (32, 32, 128), 3, 1, 1, 128, None);
        assert_eq!(l.output_spatial(), (32, 32));
        assert_eq!(l.fanin(), 1152);
        assert_eq!(l.output_pixels(), 1024);
    }

    #[test]
    fn pooling_shrinks_output() {
        let l = Layer::conv("c", LayerKind::ConvBin, (32, 32, 128), 3, 1, 1, 128, Some((2, 2)));
        assert_eq!(l.output_dims_after_pool(), (16, 16, 128));
        // AlexNet-style overlapping pool.
        let l = Layer::conv("c1", LayerKind::ConvInt, (227, 227, 3), 11, 4, 0, 96, Some((3, 2)));
        assert_eq!(l.output_spatial(), (55, 55));
        assert_eq!(l.output_dims_after_pool(), (27, 27, 96));
    }

    /// §V-C: 3×3 kernel over 32 IFMs gives the 288-input node of Table II.
    #[test]
    fn table2_fanin() {
        let l = Layer::conv("c", LayerKind::ConvBin, (16, 16, 32), 3, 1, 1, 64, None);
        assert_eq!(l.fanin(), 288);
    }

    #[test]
    fn ops_formula() {
        let l = Layer::conv("c", LayerKind::ConvBin, (32, 32, 128), 3, 1, 1, 128, None);
        // 2·128·9·1024·128 + 1024·128
        assert_eq!(l.ops(), 2 * 128 * 9 * 1024 * 128 + 1024 * 128);
        let f = Layer::fc("f", LayerKind::FcBin, 8192, 1024);
        assert_eq!(f.ops(), 2 * 8192 * 1024 + 1024);
    }

    #[test]
    fn fc_dims() {
        let f = Layer::fc("f", LayerKind::FcBin, 1024, 10);
        assert!(f.is_fc() && f.is_binary());
        assert_eq!(f.output_dims_after_pool(), (1, 1, 10));
        assert_eq!(f.weight_bits(), 10240);
    }
}

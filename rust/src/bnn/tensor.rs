//! Minimal tensors for the bit-true simulation path: binary (HWC bool) and
//! integer (HWC i32) feature maps, window extraction (im2col), and
//! deterministic synthetic data generation.

use crate::util::Rng;

/// A binary feature map, HWC layout, `{0,1}` activations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitTensor {
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// HWC-ordered activations.
    pub data: Vec<bool>,
}

impl BitTensor {
    /// All-zeros tensor.
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        BitTensor { h, w, c, data: vec![false; h * w * c] }
    }

    /// Deterministic pseudo-random contents (synthetic workloads).
    pub fn random(h: usize, w: usize, c: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        BitTensor { h, w, c, data: (0..h * w * c).map(|_| rng.gen_bool(0.5)).collect() }
    }

    /// Flat index of `(y, x, ch)`.
    #[inline]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }

    /// Activation at `(y, x, ch)`.
    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> bool {
        self.data[self.idx(y, x, ch)]
    }

    /// Set the activation at `(y, x, ch)`.
    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: bool) {
        let i = self.idx(y, x, ch);
        self.data[i] = v;
    }

    /// Zero-padded `k×k×C` window centred per the convolution geometry, in
    /// (ky, kx, c) order — the product ordering every schedule uses.
    pub fn window(&self, oy: usize, ox: usize, k: usize, stride: usize, pad: usize) -> Vec<bool> {
        let mut out = Vec::with_capacity(k * k * self.c);
        for ky in 0..k {
            for kx in 0..k {
                let y = (oy * stride + ky) as isize - pad as isize;
                let x = (ox * stride + kx) as isize - pad as isize;
                for ch in 0..self.c {
                    if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
                        out.push(false);
                    } else {
                        out.push(self.get(y as usize, x as usize, ch));
                    }
                }
            }
        }
        out
    }

    /// Allocation-free window extraction for hot loops (§Perf).
    pub fn window_into(
        &self,
        oy: usize,
        ox: usize,
        k: usize,
        stride: usize,
        pad: usize,
        out: &mut Vec<bool>,
    ) {
        out.clear();
        for ky in 0..k {
            for kx in 0..k {
                let y = (oy * stride + ky) as isize - pad as isize;
                let x = (ox * stride + kx) as isize - pad as isize;
                for ch in 0..self.c {
                    if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
                        out.push(false);
                    } else {
                        out.push(self.get(y as usize, x as usize, ch));
                    }
                }
            }
        }
    }

    /// Flatten (y, x, c) — the FC-input order.
    pub fn flatten(&self) -> Vec<bool> {
        self.data.clone()
    }

    /// Transposed window extraction for the bit-sliced engine: gather the
    /// zero-padded `k×k×C` windows of up to 64 consecutive output `pixels`
    /// (row-major over an `out_w`-wide output map) into lane words. On
    /// return `out[p]` holds product bit `p` — in the same (ky, kx, c)
    /// order as [`Self::window`] — for every pixel in the range: bit `j`
    /// belongs to pixel `pixels.start + j`. Padding contributes 0 bits,
    /// exactly like the scalar gather pushes `false`.
    pub fn window_lanes_into(
        &self,
        out_w: usize,
        k: usize,
        stride: usize,
        pad: usize,
        pixels: std::ops::Range<usize>,
        out: &mut Vec<u64>,
    ) {
        let lanes = pixels.len();
        assert!(lanes <= 64, "at most 64 pixels per lane word");
        out.clear();
        out.resize(k * k * self.c, 0);
        for (j, pixel) in pixels.enumerate() {
            let (oy, ox) = (pixel / out_w, pixel % out_w);
            let mut p = 0;
            for ky in 0..k {
                for kx in 0..k {
                    let y = (oy * stride + ky) as isize - pad as isize;
                    let x = (ox * stride + kx) as isize - pad as isize;
                    if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
                        p += self.c; // padded: leave the 0 bits in place
                        continue;
                    }
                    let base = self.idx(y as usize, x as usize, 0);
                    for &bit in &self.data[base..base + self.c] {
                        out[p] |= (bit as u64) << j;
                        p += 1;
                    }
                }
            }
        }
    }

    /// Transposed pooling-window extraction: gather the `k×k` window of
    /// channel `ch` for up to 64 consecutive output `pixels` into lane
    /// words, in (ky, kx) order. Pooling has no padding; every window is
    /// in-bounds by construction of the output geometry.
    pub fn pool_lanes_into(
        &self,
        out_w: usize,
        k: usize,
        stride: usize,
        ch: usize,
        pixels: std::ops::Range<usize>,
        out: &mut Vec<u64>,
    ) {
        let lanes = pixels.len();
        assert!(lanes <= 64, "at most 64 pixels per lane word");
        out.clear();
        out.resize(k * k, 0);
        for (j, pixel) in pixels.enumerate() {
            let (oy, ox) = (pixel / out_w, pixel % out_w);
            for ky in 0..k {
                for kx in 0..k {
                    let v = self.get(oy * stride + ky, ox * stride + kx, ch);
                    out[ky * k + kx] |= (v as u64) << j;
                }
            }
        }
    }
}

/// An integer feature map, HWC layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IntTensor {
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// HWC-ordered activations.
    pub data: Vec<i32>,
}

impl IntTensor {
    /// All-zeros tensor.
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        IntTensor { h, w, c, data: vec![0; h * w * c] }
    }

    /// Random activations within `bits`-bit unsigned range.
    pub fn random(h: usize, w: usize, c: usize, bits: u32, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let max = (1i32 << bits.min(12)) - 1;
        IntTensor {
            h,
            w,
            c,
            data: (0..h * w * c).map(|_| rng.gen_range_i64(0, max as i64) as i32).collect(),
        }
    }

    /// Flat index of `(y, x, ch)`.
    #[inline]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }

    /// Activation at `(y, x, ch)`.
    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> i32 {
        self.data[self.idx(y, x, ch)]
    }

    /// Zero-padded `k×k×C` window in (ky, kx, c) order.
    pub fn window(&self, oy: usize, ox: usize, k: usize, stride: usize, pad: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(k * k * self.c);
        for ky in 0..k {
            for kx in 0..k {
                let y = (oy * stride + ky) as isize - pad as isize;
                let x = (ox * stride + kx) as isize - pad as isize;
                for ch in 0..self.c {
                    if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
                        out.push(0);
                    } else {
                        out.push(self.get(y as usize, x as usize, ch));
                    }
                }
            }
        }
        out
    }
}

/// Binary weights for one layer: `z2` filters of `k·k·z1` ±1 weights, in
/// the same (ky, kx, c) order as [`BitTensor::window`].
#[derive(Debug, Clone)]
pub struct BinWeights {
    /// Number of output channels / filters.
    pub z2: usize,
    /// Inputs per filter (`k·k·z1`).
    pub fanin: usize,
    /// Flat ±1 weights, filter-major.
    pub data: Vec<i8>,
    /// Per-output-channel popcount thresholds (batch-norm folded in).
    pub thresholds: Vec<i64>,
}

impl BinWeights {
    /// Deterministic pseudo-random weights with balanced thresholds.
    pub fn random(z2: usize, fanin: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let data = (0..z2 * fanin).map(|_| if rng.gen_bool(0.5) { 1i8 } else { -1 }).collect();
        // Thresholds near fanin/2 keep outputs balanced (like trained BN).
        let thresholds = (0..z2)
            .map(|_| {
                let jitter = rng.gen_range_i64(-(fanin as i64) / 8, (fanin as i64) / 8);
                fanin as i64 / 2 + jitter
            })
            .collect();
        BinWeights { z2, fanin, data, thresholds }
    }

    /// Filter `o`'s weights.
    pub fn filter(&self, o: usize) -> &[i8] {
        &self.data[o * self.fanin..(o + 1) * self.fanin]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_interior_and_padding() {
        let mut t = BitTensor::zeros(4, 4, 2);
        t.set(1, 1, 0, true);
        t.set(1, 1, 1, true);
        // 3×3 window at output (0,0) with pad 1 → centre is input (0,0)…
        let w = t.window(1, 1, 3, 1, 1);
        assert_eq!(w.len(), 18);
        // centre of the window at (oy=1, ox=1) is input (1,1):
        assert!(w[(1 * 3 + 1) * 2] && w[(1 * 3 + 1) * 2 + 1]);
        // corner window is fully padded on two sides:
        let w0 = t.window(0, 0, 3, 1, 1);
        assert!(!w0[0] && !w0[1]); // (-1,-1) padded
    }

    #[test]
    fn stride_window() {
        let t = IntTensor::random(8, 8, 1, 4, 7);
        let w = t.window(1, 2, 3, 2, 0);
        assert_eq!(w[0], t.get(2, 4, 0));
        assert_eq!(w[8], t.get(4, 6, 0));
    }

    #[test]
    fn random_is_deterministic() {
        assert_eq!(BitTensor::random(4, 4, 3, 42), BitTensor::random(4, 4, 3, 42));
        assert_ne!(BitTensor::random(4, 4, 3, 42), BitTensor::random(4, 4, 3, 43));
        let w = BinWeights::random(4, 27, 1);
        assert_eq!(w.filter(2).len(), 27);
        assert!(w.thresholds.iter().all(|&t| t >= 0 && t <= 27));
    }

    /// Lane-word window gather equals the scalar gather, lane by lane —
    /// including padded borders and a ragged final lane group.
    #[test]
    fn window_lanes_match_scalar_windows() {
        let t = BitTensor::random(7, 9, 3, 99);
        let (k, stride, pad) = (3, 1, 1);
        let (oh, ow) = (7, 9); // same-size output with pad 1
        let total = oh * ow; // 63: exercises a ragged < 64 group
        let mut words = Vec::new();
        for start in [0usize, 40] {
            let end = (start + 64).min(total);
            t.window_lanes_into(ow, k, stride, pad, start..end, &mut words);
            assert_eq!(words.len(), k * k * t.c);
            for pixel in start..end {
                let j = pixel - start;
                let scalar = t.window(pixel / ow, pixel % ow, k, stride, pad);
                for (p, &bit) in scalar.iter().enumerate() {
                    assert_eq!(words[p] >> j & 1 != 0, bit, "pixel {pixel} product {p}");
                }
            }
        }
    }

    /// Lane-word pool gather equals per-element scalar reads.
    #[test]
    fn pool_lanes_match_scalar_reads() {
        let t = BitTensor::random(8, 8, 2, 5);
        let (k, stride) = (2, 2);
        let ow = 4;
        let mut words = Vec::new();
        for ch in 0..t.c {
            t.pool_lanes_into(ow, k, stride, ch, 0..16, &mut words);
            assert_eq!(words.len(), k * k);
            for pixel in 0..16 {
                let (oy, ox) = (pixel / ow, pixel % ow);
                for ky in 0..k {
                    for kx in 0..k {
                        assert_eq!(
                            words[ky * k + kx] >> pixel & 1 != 0,
                            t.get(oy * stride + ky, ox * stride + kx, ch),
                            "ch {ch} pixel {pixel} ({ky},{kx})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn int_random_respects_bits() {
        let t = IntTensor::random(8, 8, 2, 5, 3);
        assert!(t.data.iter().all(|&v| (0..32).contains(&v)));
    }
}

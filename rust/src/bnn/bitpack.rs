//! Bit-packed fast path for the functional BNN layers (§Perf, L3).
//!
//! The bool-vector reference in [`super::reference`] is the readable
//! oracle; this module packs activations and weights into `u64` words and
//! computes `popcount(xnor)` with hardware popcount — the same
//! word-parallel trick XNOR-Net software implementations use. It exists to
//! make large golden-model cross-checks and sweeps cheap; equality with
//! the slow oracle is pinned by tests, and the before/after is recorded in
//! EXPERIMENTS.md §Perf.

use super::layer::Layer;
use super::tensor::{BinWeights, BitTensor};

/// A packed bitvector: bit `i` lives at `words[i / 64] >> (i % 64)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBits {
    /// Number of valid bits.
    pub len: usize,
    /// Backing 64-bit words, LSB-first.
    pub words: Vec<u64>,
}

impl PackedBits {
    /// Pack a bool slice, bit `i` from `bits[i]`.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut words = vec![0u64; bits.len().div_ceil(64)];
        for (i, &b) in bits.iter().enumerate() {
            if b {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        PackedBits { len: bits.len(), words }
    }

    /// Weights packed as sign bits (+1 ↦ 1, −1 ↦ 0) — XNOR agreement form.
    pub fn from_weights(w: &[i8]) -> Self {
        let bools: Vec<bool> = w.iter().map(|&v| v > 0).collect();
        Self::from_bools(&bools)
    }

    /// Bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64] >> (i % 64) & 1 != 0
    }

    /// popcount(xnor(self, other)): the number of agreeing positions.
    /// Tail bits beyond `len` are masked.
    #[inline]
    pub fn xnor_popcount(&self, other: &PackedBits) -> u32 {
        debug_assert_eq!(self.len, other.len);
        let mut acc = 0u32;
        let full = self.len / 64;
        for i in 0..full {
            acc += (!(self.words[i] ^ other.words[i])).count_ones();
        }
        let rem = self.len % 64;
        if rem > 0 {
            let mask = (1u64 << rem) - 1;
            acc += ((!(self.words[full] ^ other.words[full])) & mask).count_ones();
        }
        acc
    }
}

/// Pre-packed filter bank for one layer.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    /// One packed sign-bit vector per output filter.
    pub filters: Vec<PackedBits>,
    /// Per-filter thresholds.
    pub thresholds: Vec<i64>,
}

impl PackedWeights {
    /// Pack a layer's weights into XNOR agreement form.
    pub fn pack(w: &BinWeights) -> Self {
        PackedWeights {
            filters: (0..w.z2).map(|o| PackedBits::from_weights(w.filter(o))).collect(),
            thresholds: w.thresholds.clone(),
        }
    }
}

/// FC weights transposed for the bit-sliced engine: lane words over output
/// *channels* instead of packed words over fan-in bits. Bit `j` of
/// [`Self::word`]`(wi, p)` is the sign (+1 ↦ 1) of weight `p` of output
/// channel `wi * 64 + j` — so XNORing one word against a splatted input bit
/// produces product `p` for 64 output neurons at once.
#[derive(Debug, Clone)]
pub struct LaneWeights {
    /// `words[wi * fanin + p]`: weight-sign lane word for channel group
    /// `wi`, product `p`.
    words: Vec<u64>,
    /// Inputs per filter.
    pub fanin: usize,
    /// Output channels.
    pub z2: usize,
}

impl LaneWeights {
    /// Transpose a layer's weights into channel-lane form. Channels beyond
    /// `z2` in the last group pack as 0 bits the engine never reads back.
    pub fn pack(w: &BinWeights) -> Self {
        let groups = w.z2.div_ceil(64);
        let mut words = vec![0u64; groups * w.fanin];
        for ch in 0..w.z2 {
            let (wi, j) = (ch / 64, ch % 64);
            for (p, &v) in w.filter(ch).iter().enumerate() {
                if v > 0 {
                    words[wi * w.fanin + p] |= 1 << j;
                }
            }
        }
        LaneWeights { words, fanin: w.fanin, z2: w.z2 }
    }

    /// Sign lane word for channel group `wi`, product `p`.
    #[inline]
    pub fn word(&self, wi: usize, p: usize) -> u64 {
        self.words[wi * self.fanin + p]
    }
}

/// Word-parallel binary convolution — semantically identical to
/// `reference::conv_bin`, ~50× faster for 288-bit fan-ins.
pub fn conv_bin_fast(input: &BitTensor, layer: &Layer, weights: &PackedWeights) -> BitTensor {
    let (x2, y2) = layer.output_spatial();
    let mut out = BitTensor::zeros(y2, x2, weights.filters.len());
    for oy in 0..y2 {
        for ox in 0..x2 {
            let win = PackedBits::from_bools(&input.window(
                oy,
                ox,
                layer.k,
                layer.stride,
                layer.padding,
            ));
            for (ch, f) in weights.filters.iter().enumerate() {
                let pc = win.xnor_popcount(f) as i64;
                out.set(oy, ox, ch, pc >= weights.thresholds[ch]);
            }
        }
    }
    out
}

/// Word-parallel binary FC.
pub fn fc_scores_fast(input: &[bool], weights: &PackedWeights) -> Vec<i64> {
    let win = PackedBits::from_bools(input);
    weights.filters.iter().map(|f| win.xnor_popcount(f) as i64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::layer::LayerKind;
    use crate::bnn::reference;
    use crate::neuron::function::xnor_popcount;
    use crate::util::prop::forall;

    /// Packed popcount equals the scalar oracle for arbitrary lengths
    /// (including word boundaries and tails).
    #[test]
    fn prop_packed_popcount_equals_scalar() {
        forall(
            "packed-popcount",
            120,
            |r| {
                let n = 1 + r.gen_index(300);
                let x: Vec<bool> = (0..n).map(|_| r.gen_bool(0.5)).collect();
                let w: Vec<i8> = (0..n).map(|_| if r.gen_bool(0.5) { 1 } else { -1 }).collect();
                (x, w)
            },
            |(x, w)| {
                let px = PackedBits::from_bools(x);
                let pw = PackedBits::from_weights(w);
                assert_eq!(px.xnor_popcount(&pw), xnor_popcount(x, w));
            },
        );
    }

    #[test]
    fn word_boundary_lengths() {
        for n in [63usize, 64, 65, 127, 128, 129] {
            let x: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            let w: Vec<i8> = (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
            let got = PackedBits::from_bools(&x).xnor_popcount(&PackedBits::from_weights(&w));
            assert_eq!(got, xnor_popcount(&x, &w), "n={n}");
        }
    }

    #[test]
    fn packed_get_roundtrips() {
        let bits: Vec<bool> = (0..130).map(|i| i % 5 == 0 || i % 3 == 1).collect();
        let p = PackedBits::from_bools(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(p.get(i), b, "bit {i}");
        }
    }

    /// The channel-lane transpose inverts correctly: bit `ch % 64` of word
    /// `(ch / 64, p)` is the sign of weight `p` of filter `ch`.
    #[test]
    fn lane_weights_transpose_roundtrips() {
        for z2 in [1usize, 63, 64, 65, 130] {
            let w = BinWeights::random(z2, 27, 11);
            let lanes = LaneWeights::pack(&w);
            assert_eq!((lanes.z2, lanes.fanin), (z2, 27));
            for ch in 0..z2 {
                for (p, &v) in w.filter(ch).iter().enumerate() {
                    assert_eq!(
                        lanes.word(ch / 64, p) >> (ch % 64) & 1 != 0,
                        v > 0,
                        "z2={z2} ch={ch} p={p}"
                    );
                }
            }
        }
    }

    #[test]
    fn conv_fast_equals_reference() {
        let layer = Layer::conv("c", LayerKind::ConvBin, (7, 7, 5), 3, 1, 1, 6, None);
        let input = BitTensor::random(7, 7, 5, 4);
        let weights = BinWeights::random(6, layer.fanin(), 9);
        let fast = conv_bin_fast(&input, &layer, &PackedWeights::pack(&weights));
        assert_eq!(fast, reference::conv_bin(&input, &layer, &weights));
    }

    #[test]
    fn fc_fast_equals_reference() {
        let layer = Layer::fc("f", LayerKind::FcBin, 100, 7);
        let weights = BinWeights::random(7, 100, 3);
        let input: Vec<bool> = (0..100).map(|i| i % 7 < 3).collect();
        assert_eq!(
            fc_scores_fast(&input, &PackedWeights::pack(&weights)),
            reference::fc_scores(&input, &layer, &weights)
        );
    }
}

//! The paper's evaluation workloads (§V): BinaryNet on CIFAR-10
//! (Courbariaux et al. [9]) and AlexNet on ImageNet (XNOR-Net variant
//! [10][30]), plus a tiny synthetic BNN for bit-true end-to-end validation.

use super::layer::{Layer, LayerKind};
use super::Network;

/// BinaryNet's CIFAR-10 topology [9]: six 3×3 conv layers
/// (128-128-256-256-512-512, pooling after every second) and three FC
/// layers (8192→1024→1024→10). First conv takes 8-bit-ish integer pixels
/// (processed on the 12-bit datapath); everything downstream is binary.
pub fn binarynet_cifar10() -> Network {
    use LayerKind::*;
    Network {
        name: "BinaryNet".into(),
        dataset: "CIFAR10".into(),
        layers: vec![
            Layer::conv("conv1", ConvInt, (32, 32, 3), 3, 1, 1, 128, None),
            Layer::conv("conv2", ConvBin, (32, 32, 128), 3, 1, 1, 128, Some((2, 2))),
            Layer::conv("conv3", ConvBin, (16, 16, 128), 3, 1, 1, 256, None),
            Layer::conv("conv4", ConvBin, (16, 16, 256), 3, 1, 1, 256, Some((2, 2))),
            Layer::conv("conv5", ConvBin, (8, 8, 256), 3, 1, 1, 512, None),
            Layer::conv("conv6", ConvBin, (8, 8, 512), 3, 1, 1, 512, Some((2, 2))),
            Layer::fc("fc1", FcBin, 8192, 1024),
            Layer::fc("fc2", FcBin, 1024, 1024),
            Layer::fc("fc3", FcBin, 1024, 10),
        ],
    }
}

/// AlexNet (XNOR-Net binarization [30]): integer conv1/conv2, binary
/// conv3–conv5 and FC stack — the layer split Table III uses. conv1 is
/// processed in 4 image parts (Table III: "Parts 4").
pub fn alexnet() -> Network {
    use LayerKind::*;
    Network {
        name: "AlexNet".into(),
        dataset: "Imagenet".into(),
        layers: vec![
            Layer::conv("conv1", ConvInt, (227, 227, 3), 11, 4, 0, 96, Some((3, 2))).with_parts(4),
            Layer::conv("conv2", ConvInt, (27, 27, 96), 5, 1, 2, 256, Some((3, 2))),
            Layer::conv("conv3", ConvBin, (13, 13, 256), 3, 1, 1, 384, None),
            Layer::conv("conv4", ConvBin, (13, 13, 384), 3, 1, 1, 384, None),
            Layer::conv("conv5", ConvBin, (13, 13, 384), 3, 1, 1, 256, Some((3, 2))),
            Layer::fc("fc6", FcBin, 9216, 4096),
            Layer::fc("fc7", FcBin, 4096, 4096),
            Layer::fc("fc8", FcBin, 4096, 1000),
        ],
    }
}

/// The MNIST MLP of the original BinaryNet evaluation [9] (the paper cites
/// MNIST/SVHN/CIFAR-10 as the BNN accuracy anchors): 784 → 3×4096 → 10,
/// all binary after the integer input layer.
pub fn mnist_mlp() -> Network {
    use LayerKind::*;
    Network {
        name: "BinaryNet-MLP".into(),
        dataset: "MNIST".into(),
        layers: vec![
            Layer::fc("fc1", FcInt, 784, 4096),
            Layer::fc("fc2", FcBin, 4096, 4096),
            Layer::fc("fc3", FcBin, 4096, 4096),
            Layer::fc("fc4", FcBin, 4096, 10),
        ],
    }
}

/// The SVHN convnet of BinaryNet [9]: same topology family as the CIFAR-10
/// network at half the width (64-64-128-128-256-256 + 1024-unit FCs).
pub fn svhn_net() -> Network {
    use LayerKind::*;
    Network {
        name: "BinaryNet-SVHN".into(),
        dataset: "SVHN".into(),
        layers: vec![
            Layer::conv("conv1", ConvInt, (32, 32, 3), 3, 1, 1, 64, None),
            Layer::conv("conv2", ConvBin, (32, 32, 64), 3, 1, 1, 64, Some((2, 2))),
            Layer::conv("conv3", ConvBin, (16, 16, 64), 3, 1, 1, 128, None),
            Layer::conv("conv4", ConvBin, (16, 16, 128), 3, 1, 1, 128, Some((2, 2))),
            Layer::conv("conv5", ConvBin, (8, 8, 128), 3, 1, 1, 256, None),
            Layer::conv("conv6", ConvBin, (8, 8, 256), 3, 1, 1, 256, Some((2, 2))),
            Layer::fc("fc1", FcBin, 4096, 1024),
            Layer::fc("fc2", FcBin, 1024, 1024),
            Layer::fc("fc3", FcBin, 1024, 10),
        ],
    }
}

/// A tiny synthetic BNN (`size`×`size` input, `ch` channels, `classes`
/// outputs) small enough to push through the **bit-true** PE simulation and
/// cross-check against the JAX golden model (examples/e2e_inference.rs).
pub fn tiny_bnn(size: usize, ch: usize, classes: usize) -> Network {
    use LayerKind::*;
    assert!(size >= 8 && size % 4 == 0);
    let half = size / 2;
    let flat = (half / 2) * (half / 2) * (2 * ch);
    Network {
        name: format!("TinyBNN-{size}x{size}x{ch}"),
        dataset: "synthetic".into(),
        layers: vec![
            Layer::conv("conv1", ConvBin, (size, size, ch), 3, 1, 1, ch, Some((2, 2))),
            Layer::conv("conv2", ConvBin, (half, half, ch), 3, 1, 1, 2 * ch, Some((2, 2))),
            Layer::fc("fc", FcBin, flat, classes),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extra_networks_validate() {
        mnist_mlp().validate().unwrap();
        svhn_net().validate().unwrap();
        // SVHN conv stack feeds 4·4·256 = 4096 into fc1.
        assert_eq!(svhn_net().layers[6].z1, 4096);
        // MNIST MLP is FC-only.
        assert!(mnist_mlp().layers.iter().all(|l| l.is_fc()));
    }

    /// Table III's layer parameters are reproduced by the AlexNet topology:
    /// z1/z2 per conv layer drive the P/Z columns (checked end-to-end in
    /// coordinator::tiling).
    #[test]
    fn alexnet_table3_dims() {
        let n = alexnet();
        let convs: Vec<&Layer> = n.conv_layers().collect();
        assert_eq!(convs.len(), 5);
        assert_eq!((convs[0].z1, convs[0].z2, convs[0].image_parts), (3, 96, 4));
        assert_eq!((convs[1].z1, convs[1].z2), (96, 256));
        assert_eq!((convs[2].z1, convs[2].z2), (256, 384));
        assert_eq!((convs[3].z1, convs[3].z2), (384, 384));
        assert_eq!((convs[4].z1, convs[4].z2), (384, 256));
        assert!(convs[2].is_binary() && !convs[1].is_binary());
    }

    #[test]
    fn binarynet_shape_chain() {
        let n = binarynet_cifar10();
        n.validate().unwrap();
        let last_conv = n.conv_layers().last().unwrap();
        assert_eq!(last_conv.output_dims_after_pool(), (4, 4, 512));
        // 4·4·512 = 8192 feeds fc1.
        assert_eq!(n.layers[6].z1, 8192);
    }

    #[test]
    fn tiny_bnn_dims() {
        let n = tiny_bnn(16, 8, 4);
        n.validate().unwrap();
        assert_eq!(n.layers[2].z1, 4 * 4 * 16);
    }

    /// Only conv1 (and conv2 for AlexNet) are integer; the rest binary —
    /// this drives the MAC-vs-PE split in the coordinator.
    #[test]
    fn integer_binary_split() {
        assert_eq!(binarynet_cifar10().layers.iter().filter(|l| !l.is_binary()).count(), 1);
        assert_eq!(alexnet().layers.iter().filter(|l| !l.is_binary()).count(), 2);
    }
}

//! BNN intermediate representation: layers, networks, the evaluation
//! workloads of §V (BinaryNet-CIFAR10 and AlexNet-ImageNet), operation
//! counting per the paper's formulas, and bit-true tensor references.

pub mod bitpack;
pub mod layer;
pub mod model;
pub mod reference;
pub mod tensor;
pub mod zoo;

pub use layer::{Layer, LayerKind};
pub use model::Model;
pub use zoo::{alexnet, binarynet_cifar10, mnist_mlp, svhn_net, tiny_bnn};

use crate::error::Error;

/// A BNN as a sequence of layers (the DAG of §I specialized to the chain
/// topology both evaluation networks have).
#[derive(Debug, Clone)]
pub struct Network {
    /// Network name (e.g. "AlexNet").
    pub name: String,
    /// Dataset label (e.g. "ImageNet").
    pub dataset: String,
    /// Layers in forward order.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total operations in MOp, counted as the paper does (§V-C): for a 2-D
    /// convolution layer `2·z1·k²·x2·y2·z2` multiply/accumulate operations
    /// plus `x2·y2·z2` comparisons.
    pub fn total_mops(&self) -> f64 {
        self.layers.iter().map(|l| l.ops() as f64).sum::<f64>() / 1e6
    }

    /// MOp restricted to convolution layers (Table IV scope).
    pub fn conv_mops(&self) -> f64 {
        self.layers.iter().filter(|l| l.is_conv()).map(|l| l.ops() as f64).sum::<f64>() / 1e6
    }

    /// MOp restricted to fully connected layers.
    pub fn fc_mops(&self) -> f64 {
        self.total_mops() - self.conv_mops()
    }

    /// Convolution layers only (Table IV), preserving order.
    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.is_conv())
    }

    /// Sanity-check layer chaining: each layer's input dims must match the
    /// previous layer's output dims.
    pub fn validate(&self) -> Result<(), Error> {
        if self.layers.is_empty() {
            return Err(Error::InvalidNetwork(format!("network '{}' has no layers", self.name)));
        }
        for w in self.layers.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            let (ox, oy, oz) = a.output_dims_after_pool();
            let flat_ok = b.is_fc() && b.z1 == ox * oy * oz;
            let dims_ok = b.x1 == ox && b.y1 == oy && b.z1 == oz;
            if !(dims_ok || flat_ok) {
                return Err(Error::InvalidNetwork(format!(
                    "layer '{}' output {:?} does not feed '{}' input ({},{},{})",
                    a.name,
                    (ox, oy, oz),
                    b.name,
                    b.x1,
                    b.y1,
                    b.z1
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §V anchors: the paper's op counts. Conv totals depend on the exact
    /// padding convention; the FC splits match the paper to < 1 MOp
    /// (Table V − Table IV: 19 MOp for BinaryNet, 118 MOp for AlexNet).
    #[test]
    fn fc_mops_match_paper_deltas() {
        let b = binarynet_cifar10();
        assert!((b.fc_mops() - 19.0).abs() < 1.5, "BinaryNet FC MOp: {}", b.fc_mops());
        let a = alexnet();
        assert!((a.fc_mops() - 118.0).abs() < 3.0, "AlexNet FC MOp: {}", a.fc_mops());
    }

    #[test]
    fn conv_mops_same_regime_as_paper() {
        // Paper: 1017 MOp (BinaryNet conv), 2050 MOp (AlexNet conv). Our
        // padding conventions land within ~25% — same regime; EXPERIMENTS.md
        // reports the exact deltas.
        let b = binarynet_cifar10().conv_mops();
        assert!(b > 700.0 && b < 1400.0, "BinaryNet conv MOp {b}");
        let a = alexnet().conv_mops();
        assert!(a > 1600.0 && a < 2600.0, "AlexNet conv MOp {a}");
    }

    #[test]
    fn networks_validate() {
        binarynet_cifar10().validate().unwrap();
        alexnet().validate().unwrap();
        tiny_bnn(16, 8, 2).validate().unwrap();
    }

    #[test]
    fn conv_fc_partition() {
        let n = binarynet_cifar10();
        let total = n.total_mops();
        assert!((n.conv_mops() + n.fc_mops() - total).abs() < 1e-9);
        assert_eq!(n.conv_layers().count(), 6);
    }
}

//! `model` — the loadable BNN artifact the rest of the crate consumes.
//!
//! TULIP's premise is an *arbitrary* BNN executing on a fixed PE fabric
//! (§IV mapping algorithms), so the network description is data, not code:
//! a [`Model`] owns a validated [`Network`] plus its per-layer weights and
//! lazily builds the engine-specific packings
//! ([`SlicedWeights`]/[`PackedWeights`]) on first use. The type is a cheap
//! `Arc` handle — clones share the caches — which is what lets the serve
//! registry hand the same artifact to an executor, a batcher lane and an
//! oracle client without re-packing.
//!
//! ## On-disk format: `tulip.model/v1`
//!
//! One JSON document (the std-only parser/encoder shared with
//! [`serve::protocol`](crate::serve::protocol) — no serde in the
//! dependency set):
//!
//! ```json
//! {"schema": "tulip.model/v1", "name": "tiny-bnn-16", "dataset": "synthetic",
//!  "layers": [{"name": "conv1", "kind": "conv_bin", "x1": 16, "y1": 16,
//!              "z1": 8, "k": 3, "stride": 1, "padding": 1, "z2": 8,
//!              "pool": [2, 2], "image_parts": 1}, …],
//!  "weights": [{"signs": "a3f0…", "thresholds": [36, 41, …]}, …]}
//! ```
//!
//! `signs` is the layer's ±1 weight matrix, filter-major, one bit per
//! weight (`+1 → 1`), packed LSB-first into bytes and hex-encoded exactly
//! like wire activations ([`pack_bits`]). `thresholds` are the per-channel
//! popcount thresholds with batch-norm folded in. Every structural
//! mistake — bad JSON, missing field, wrong blob length, unchained layers
//! — surfaces as a typed [`Error`], never a panic.

use super::tensor::{BinWeights, BitTensor};
use super::{Layer, LayerKind, Network};
use crate::arch::unit::{PeArray, SlicedArray};
use crate::bnn::bitpack::PackedWeights;
use crate::error::Error;
use crate::scheduler::seqgen::SequenceGenerator;
use crate::serve::protocol::{json_str, pack_bits, parse_json, unpack_bits, Json};
use crate::sim::cycle::{ForwardResult, SlicedWeights};
use std::path::Path;
use std::sync::{Arc, OnceLock};

/// The `schema` string every `tulip.model/v1` document must carry.
pub const MODEL_SCHEMA: &str = "tulip.model/v1";

/// A validated, immutable BNN artifact: network description + weights +
/// lazily-built engine packings. Cheap to clone (an `Arc` handle); see the
/// [module docs](self) for the on-disk format.
#[derive(Debug, Clone)]
pub struct Model {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    net: Network,
    weights: Vec<BinWeights>,
    sliced: OnceLock<SlicedWeights>,
    packed: OnceLock<Vec<PackedWeights>>,
}

impl Model {
    /// Build a model from a network and its per-layer weights, validating
    /// layer chaining and weight shapes. This is the only constructor —
    /// every loaded or assembled model has passed it.
    pub fn from_parts(
        net: Network,
        weights: Vec<BinWeights>,
    ) -> std::result::Result<Self, Error> {
        net.validate()?;
        if weights.len() != net.layers.len() {
            return Err(Error::InvalidNetwork(format!(
                "{} weight sets for {} layers",
                weights.len(),
                net.layers.len()
            )));
        }
        for (l, w) in net.layers.iter().zip(&weights) {
            if w.z2 != l.z2 || w.fanin != l.fanin() {
                return Err(Error::InvalidNetwork(format!(
                    "layer '{}' expects {}×{} weights, got {}×{}",
                    l.name,
                    l.z2,
                    l.fanin(),
                    w.z2,
                    w.fanin
                )));
            }
            if w.data.len() != w.z2 * w.fanin {
                return Err(Error::InvalidNetwork(format!(
                    "layer '{}' weight blob holds {} entries, expected {}",
                    l.name,
                    w.data.len(),
                    w.z2 * w.fanin
                )));
            }
            if w.thresholds.len() != l.z2 {
                return Err(Error::InvalidNetwork(format!(
                    "layer '{}' has {} thresholds for {} output channels",
                    l.name,
                    w.thresholds.len(),
                    l.z2
                )));
            }
        }
        Ok(Model {
            inner: Arc::new(Inner {
                net,
                weights,
                sliced: OnceLock::new(),
                packed: OnceLock::new(),
            }),
        })
    }

    /// A model with deterministic pseudo-random weights: layer `i` gets
    /// [`BinWeights::random`] seeded `base_seed + i`. The seeding scheme is
    /// part of the crate's compatibility surface — clients and servers
    /// built independently from the same `(network, base_seed)` match bit
    /// for bit.
    pub fn random(net: Network, base_seed: u64) -> std::result::Result<Self, Error> {
        let weights = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), base_seed + i as u64))
            .collect();
        Model::from_parts(net, weights)
    }

    /// The demo models `tulip serve`, `load_client` and the integration
    /// tests agree on, keyed by name (weights seeded with base 1000, see
    /// [`Model::random`]): `"tiny"` → `tiny_bnn(16, 8, 4)` (16×16×8
    /// input), `"tiny8"` → `tiny_bnn(8, 4, 3)` (8×8×4 input).
    pub fn demo(name: &str) -> Option<Model> {
        let net = match name {
            "tiny" => super::tiny_bnn(16, 8, 4),
            "tiny8" => super::tiny_bnn(8, 4, 3),
            _ => return None,
        };
        Some(Model::random(net, 1000).expect("demo networks are valid by construction"))
    }

    /// The network description.
    pub fn network(&self) -> &Network {
        &self.inner.net
    }

    /// Per-layer weights, index-aligned with `network().layers`.
    pub fn weights(&self) -> &[BinWeights] {
        &self.inner.weights
    }

    /// The network's name.
    pub fn name(&self) -> &str {
        &self.inner.net.name
    }

    /// Input geometry `(h, w, c)` of the first layer.
    pub fn input_dims(&self) -> (usize, usize, usize) {
        let l0 = &self.inner.net.layers[0];
        (l0.y1, l0.x1, l0.z1)
    }

    /// Number of classes (output length of the final layer).
    pub fn num_classes(&self) -> usize {
        self.inner.net.layers.last().expect("validated networks are non-empty").z2
    }

    /// Total weight bits across all layers.
    pub fn weight_bits(&self) -> u64 {
        self.inner.net.layers.iter().map(|l| l.weight_bits()).sum()
    }

    /// Can the serving engines run this model bit-true? Requires every
    /// layer binary (integer layers route to MACs the simulator does not
    /// serve, §V-C) and an FC classifier head.
    pub fn servable(&self) -> std::result::Result<(), Error> {
        for l in &self.inner.net.layers {
            if !l.is_binary() {
                return Err(Error::Unservable(format!(
                    "layer '{}' is integer ({:?}); the bit-true engines serve binary layers only",
                    l.name, l.kind
                )));
            }
        }
        let last = self.inner.net.layers.last().expect("validated networks are non-empty");
        if !last.is_fc() {
            return Err(Error::Unservable(format!(
                "final layer '{}' is not fully connected — no classifier head to read scores from",
                last.name
            )));
        }
        Ok(())
    }

    /// The bit-sliced engine's per-layer weight packing, built on first
    /// use and shared by every clone of this model.
    pub fn sliced(&self) -> &SlicedWeights {
        self.inner
            .sliced
            .get_or_init(|| SlicedWeights::pack(&self.inner.net, &self.inner.weights))
    }

    /// Per-layer sign-packed filters ([`PackedWeights`]), built on first
    /// use and shared by every clone of this model.
    pub fn packed(&self) -> &[PackedWeights] {
        self.inner.packed.get_or_init(|| self.inner.weights.iter().map(PackedWeights::pack).collect())
    }

    /// Bit-true whole-network forward pass on the scalar engine (the
    /// readable reference oracle).
    pub fn forward_scalar(
        &self,
        array: &mut PeArray,
        sg: &mut SequenceGenerator,
        input: &BitTensor,
    ) -> ForwardResult {
        crate::sim::cycle::forward_scalar_impl(array, sg, input, &self.inner.net, &self.inner.weights)
    }

    /// Bit-true whole-network forward pass on the 64-lane bit-sliced
    /// engine — bit-identical to [`Model::forward_scalar`].
    pub fn forward_sliced(
        &self,
        arr: &mut SlicedArray,
        sg: &mut SequenceGenerator,
        input: &BitTensor,
    ) -> ForwardResult {
        crate::sim::cycle::forward_sliced_impl(
            arr,
            sg,
            input,
            &self.inner.net,
            &self.inner.weights,
            self.sliced(),
        )
    }

    /// Encode as one compact `tulip.model/v1` JSON line (single-line by
    /// design, so an artifact can ride the JSON-lines wire protocol
    /// unmodified — see the `load_model` op).
    pub fn to_json(&self) -> String {
        let net = &self.inner.net;
        let layers: Vec<String> = net.layers.iter().map(layer_json).collect();
        let weights: Vec<String> = self.inner.weights.iter().map(weight_json).collect();
        format!(
            "{{\"schema\": {}, \"name\": {}, \"dataset\": {}, \"layers\": [{}], \"weights\": [{}]}}",
            json_str(MODEL_SCHEMA),
            json_str(&net.name),
            json_str(&net.dataset),
            layers.join(", "),
            weights.join(", ")
        )
    }

    /// Decode a `tulip.model/v1` document.
    pub fn from_json(s: &str) -> std::result::Result<Self, Error> {
        let v = parse_json(s).map_err(|e| Error::ModelFormat(format!("{e:#}")))?;
        Model::from_json_value(&v)
    }

    /// Decode an already-parsed `tulip.model/v1` document (the `load_model`
    /// wire op arrives pre-parsed inside its request line).
    pub fn from_json_value(v: &Json) -> std::result::Result<Self, Error> {
        let schema = str_field(v, "schema")?;
        if schema != MODEL_SCHEMA {
            return Err(Error::UnsupportedVersion {
                found: schema.to_string(),
                expected: MODEL_SCHEMA,
            });
        }
        let name = str_field(v, "name")?.to_string();
        let dataset = str_field(v, "dataset")?.to_string();
        let layers = arr_field(v, "layers")?
            .iter()
            .enumerate()
            .map(|(i, l)| layer_from_json(l).map_err(|e| e.in_context(&format!("layers[{i}]"))))
            .collect::<std::result::Result<Vec<Layer>, Error>>()?;
        let wdocs = arr_field(v, "weights")?;
        if wdocs.len() != layers.len() {
            return Err(Error::ModelFormat(format!(
                "{} weight blobs for {} layers",
                wdocs.len(),
                layers.len()
            )));
        }
        let weights = layers
            .iter()
            .zip(wdocs)
            .enumerate()
            .map(|(i, (l, w))| {
                weights_from_json(w, l).map_err(|e| e.in_context(&format!("weights[{i}]")))
            })
            .collect::<std::result::Result<Vec<BinWeights>, Error>>()?;
        Model::from_parts(Network { name, dataset, layers }, weights)
    }

    /// Load a model artifact from disk.
    pub fn load(path: impl AsRef<Path>) -> std::result::Result<Self, Error> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|source| Error::Io { path: path.display().to_string(), source })?;
        Model::from_json(text.trim())
    }

    /// Write the model artifact to disk (one JSON line + newline).
    pub fn save(&self, path: impl AsRef<Path>) -> std::result::Result<(), Error> {
        let path = path.as_ref();
        let mut text = self.to_json();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|source| Error::Io { path: path.display().to_string(), source })
    }
}

impl Error {
    /// Prefix a `ModelFormat` message with its document location.
    fn in_context(self, ctx: &str) -> Error {
        match self {
            Error::ModelFormat(m) => Error::ModelFormat(format!("{ctx}: {m}")),
            other => other,
        }
    }
}

fn kind_name(k: LayerKind) -> &'static str {
    match k {
        LayerKind::ConvInt => "conv_int",
        LayerKind::ConvBin => "conv_bin",
        LayerKind::FcInt => "fc_int",
        LayerKind::FcBin => "fc_bin",
    }
}

fn kind_from_name(s: &str) -> std::result::Result<LayerKind, Error> {
    match s {
        "conv_int" => Ok(LayerKind::ConvInt),
        "conv_bin" => Ok(LayerKind::ConvBin),
        "fc_int" => Ok(LayerKind::FcInt),
        "fc_bin" => Ok(LayerKind::FcBin),
        other => Err(Error::ModelFormat(format!(
            "unknown layer kind '{other}' (conv_int|conv_bin|fc_int|fc_bin)"
        ))),
    }
}

fn layer_json(l: &Layer) -> String {
    let pool = match l.pool {
        Some((k, s)) => format!("[{k}, {s}]"),
        None => "null".into(),
    };
    format!(
        "{{\"name\": {}, \"kind\": {}, \"x1\": {}, \"y1\": {}, \"z1\": {}, \"k\": {}, \
         \"stride\": {}, \"padding\": {}, \"z2\": {}, \"pool\": {}, \"image_parts\": {}}}",
        json_str(&l.name),
        json_str(kind_name(l.kind)),
        l.x1,
        l.y1,
        l.z1,
        l.k,
        l.stride,
        l.padding,
        l.z2,
        pool,
        l.image_parts
    )
}

fn weight_json(w: &BinWeights) -> String {
    let signs: Vec<bool> = w.data.iter().map(|&v| v > 0).collect();
    let thresholds: Vec<String> = w.thresholds.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"signs\": {}, \"thresholds\": [{}]}}",
        json_str(&pack_bits(&signs)),
        thresholds.join(", ")
    )
}

fn str_field<'a>(v: &'a Json, key: &str) -> std::result::Result<&'a str, Error> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| Error::ModelFormat(format!("missing string field '{key}'")))
}

fn usize_field(v: &Json, key: &str) -> std::result::Result<usize, Error> {
    v.get(key)
        .and_then(Json::as_u64)
        .map(|n| n as usize)
        .ok_or_else(|| Error::ModelFormat(format!("missing non-negative integer field '{key}'")))
}

fn arr_field<'a>(v: &'a Json, key: &str) -> std::result::Result<&'a [Json], Error> {
    match v.get(key) {
        Some(Json::Arr(items)) => Ok(items),
        _ => Err(Error::ModelFormat(format!("missing array field '{key}'"))),
    }
}

fn layer_from_json(v: &Json) -> std::result::Result<Layer, Error> {
    let kind = kind_from_name(str_field(v, "kind")?)?;
    let pool = match v.get("pool") {
        None | Some(Json::Null) => None,
        Some(Json::Arr(items)) => {
            let two: Vec<usize> =
                items.iter().filter_map(Json::as_u64).map(|n| n as usize).collect();
            if two.len() != 2 || two.len() != items.len() {
                return Err(Error::ModelFormat(
                    "'pool' must be null or a [window, stride] pair".into(),
                ));
            }
            Some((two[0], two[1]))
        }
        Some(_) => {
            return Err(Error::ModelFormat("'pool' must be null or a [window, stride] pair".into()))
        }
    };
    Ok(Layer {
        name: str_field(v, "name")?.to_string(),
        kind,
        x1: usize_field(v, "x1")?,
        y1: usize_field(v, "y1")?,
        z1: usize_field(v, "z1")?,
        k: usize_field(v, "k")?,
        stride: usize_field(v, "stride")?,
        padding: usize_field(v, "padding")?,
        z2: usize_field(v, "z2")?,
        pool,
        input_bits: if matches!(kind, LayerKind::ConvInt | LayerKind::FcInt) { 12 } else { 1 },
        image_parts: usize_field(v, "image_parts")?,
    })
}

fn weights_from_json(v: &Json, layer: &Layer) -> std::result::Result<BinWeights, Error> {
    let n = layer.z2 * layer.fanin();
    let hex = str_field(v, "signs")?;
    let signs = unpack_bits(hex, n).map_err(|e| Error::ModelFormat(format!("'signs': {e:#}")))?;
    let data: Vec<i8> = signs.iter().map(|&b| if b { 1i8 } else { -1 }).collect();
    let Some(Json::Arr(items)) = v.get("thresholds") else {
        return Err(Error::ModelFormat("missing array field 'thresholds'".into()));
    };
    let thresholds: Vec<i64> = items.iter().filter_map(Json::as_i64).collect();
    if thresholds.len() != items.len() {
        return Err(Error::ModelFormat("non-integer threshold".into()));
    }
    if thresholds.len() != layer.z2 {
        return Err(Error::ModelFormat(format!(
            "{} thresholds for {} output channels",
            thresholds.len(),
            layer.z2
        )));
    }
    Ok(BinWeights { z2: layer.z2, fanin: layer.fanin(), data, thresholds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::tiny_bnn;

    #[test]
    fn from_parts_validates_shapes() {
        let net = tiny_bnn(8, 4, 3);
        let mut weights: Vec<BinWeights> = net
            .layers
            .iter()
            .map(|l| BinWeights::random(l.z2, l.fanin(), 7))
            .collect();
        assert!(Model::from_parts(net.clone(), weights.clone()).is_ok());
        weights[1].thresholds.pop();
        match Model::from_parts(net.clone(), weights).unwrap_err() {
            Error::InvalidNetwork(m) => assert!(m.contains("thresholds"), "{m}"),
            other => panic!("expected InvalidNetwork, got {other:?}"),
        }
        match Model::from_parts(net, Vec::new()).unwrap_err() {
            Error::InvalidNetwork(m) => assert!(m.contains("weight sets"), "{m}"),
            other => panic!("expected InvalidNetwork, got {other:?}"),
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let m = Model::demo("tiny8").unwrap();
        let back = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(back.to_json(), m.to_json());
        assert_eq!(back.network().layers.len(), m.network().layers.len());
        for (a, b) in back.weights().iter().zip(m.weights()) {
            assert_eq!(a.data, b.data);
            assert_eq!(a.thresholds, b.thresholds);
        }
    }

    #[test]
    fn wrong_schema_is_typed() {
        let doc =
            Model::demo("tiny8").unwrap().to_json().replace("tulip.model/v1", "tulip.model/v9");
        match Model::from_json(&doc).unwrap_err() {
            Error::UnsupportedVersion { found, expected } => {
                assert_eq!(found, "tulip.model/v9");
                assert_eq!(expected, MODEL_SCHEMA);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn servable_gates_integer_and_headless_nets() {
        assert!(Model::demo("tiny").unwrap().servable().is_ok());
        let alex = Model::random(crate::bnn::alexnet(), 3).unwrap();
        assert!(matches!(alex.servable(), Err(Error::Unservable(_))));
    }

    #[test]
    fn caches_are_shared_across_clones() {
        let m = Model::demo("tiny8").unwrap();
        let c = m.clone();
        let a = m.sliced() as *const SlicedWeights;
        let b = c.sliced() as *const SlicedWeights;
        assert_eq!(a, b, "clones share the lazily-built packing");
        assert_eq!(m.packed().len(), m.network().layers.len());
    }
}

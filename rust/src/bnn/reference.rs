//! Functional (non-timed) reference implementations of every BNN layer —
//! the rust-side oracle the bit-true PE simulation is checked against.
//! (The JAX golden model in `python/compile` provides an independent
//! second oracle through the PJRT runtime.)

use super::layer::Layer;
use super::tensor::{BinWeights, BitTensor, IntTensor};
use crate::neuron::function::xnor_popcount;

/// Binary convolution: XNOR-popcount + threshold, with zero padding.
/// Output `o(y,x,ch) = [popcount(xnor(window, w_ch)) ≥ T'_ch]`.
pub fn conv_bin(input: &BitTensor, layer: &Layer, weights: &BinWeights) -> BitTensor {
    assert_eq!(input.c, layer.z1);
    assert_eq!(weights.fanin, layer.fanin());
    assert_eq!(weights.z2, layer.z2);
    let (x2, y2) = layer.output_spatial();
    let mut out = BitTensor::zeros(y2, x2, layer.z2);
    for oy in 0..y2 {
        for ox in 0..x2 {
            let win = input.window(oy, ox, layer.k, layer.stride, layer.padding);
            for ch in 0..layer.z2 {
                let pc = xnor_popcount(&win, weights.filter(ch)) as i64;
                out.set(oy, ox, ch, pc >= weights.thresholds[ch]);
            }
        }
    }
    out
}

/// Integer convolution with binary weights (first layers): signed
/// weighted sum then threshold.
pub fn conv_int(input: &IntTensor, layer: &Layer, weights: &BinWeights) -> BitTensor {
    assert_eq!(input.c, layer.z1);
    let (x2, y2) = layer.output_spatial();
    let mut out = BitTensor::zeros(y2, x2, layer.z2);
    for oy in 0..y2 {
        for ox in 0..x2 {
            let win = input.window(oy, ox, layer.k, layer.stride, layer.padding);
            for ch in 0..layer.z2 {
                let s: i64 = win
                    .iter()
                    .zip(weights.filter(ch))
                    .map(|(&x, &w)| x as i64 * w as i64)
                    .sum();
                out.set(oy, ox, ch, s >= weights.thresholds[ch]);
            }
        }
    }
    out
}

/// Max-pooling on a binary map = OR over the window (§IV-D).
pub fn maxpool(input: &BitTensor, k: usize, stride: usize) -> BitTensor {
    let oh = (input.h - k) / stride + 1;
    let ow = (input.w - k) / stride + 1;
    let mut out = BitTensor::zeros(oh, ow, input.c);
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..input.c {
                let mut v = false;
                for ky in 0..k {
                    for kx in 0..k {
                        v |= input.get(oy * stride + ky, ox * stride + kx, ch);
                    }
                }
                out.set(oy, ox, ch, v);
            }
        }
    }
    out
}

/// Binary fully connected layer on a flattened input.
pub fn fc_bin(input: &[bool], layer: &Layer, weights: &BinWeights) -> Vec<bool> {
    assert_eq!(input.len(), layer.z1);
    assert_eq!(weights.fanin, layer.z1);
    (0..layer.z2)
        .map(|ch| xnor_popcount(input, weights.filter(ch)) as i64 >= weights.thresholds[ch])
        .collect()
}

/// Binary FC returning raw popcounts (the last layer of a classifier keeps
/// scores for argmax instead of binarizing).
pub fn fc_scores(input: &[bool], layer: &Layer, weights: &BinWeights) -> Vec<i64> {
    (0..layer.z2).map(|ch| xnor_popcount(input, weights.filter(ch)) as i64).collect()
}

/// Run a whole binary network functionally; returns final-layer scores.
/// Panics on integer layers (use the tiny all-binary zoo entry for this).
pub fn forward_scores(
    net: &super::Network,
    input: &BitTensor,
    weights: &[BinWeights],
) -> Vec<i64> {
    assert_eq!(net.layers.len(), weights.len());
    let mut act = input.clone();
    let mut flat: Option<Vec<bool>> = None;
    for (i, (layer, w)) in net.layers.iter().zip(weights).enumerate() {
        let last = i + 1 == net.layers.len();
        if layer.is_conv() {
            assert!(layer.is_binary(), "forward_scores handles binary nets only");
            let mut o = conv_bin(&act, layer, w);
            if let Some((pk, ps)) = layer.pool {
                o = maxpool(&o, pk, ps);
            }
            act = o;
        } else {
            let input_flat = flat.take().unwrap_or_else(|| act.flatten());
            if last {
                return fc_scores(&input_flat, layer, w);
            }
            flat = Some(fc_bin(&input_flat, layer, w));
        }
    }
    unreachable!("network must end in an FC layer");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::layer::LayerKind;
    use crate::bnn::zoo::tiny_bnn;

    #[test]
    fn conv_bin_known_values() {
        // 1 input channel, all-ones 3×3 image, weight filter of all +1,
        // threshold 5: interior pixels see 9 ones (with pad), corners 4.
        let mut input = BitTensor::zeros(3, 3, 1);
        for i in 0..9 {
            input.data[i] = true;
        }
        let layer = Layer::conv("t", LayerKind::ConvBin, (3, 3, 1), 3, 1, 1, 1, None);
        let weights = BinWeights {
            z2: 1,
            fanin: 9,
            data: vec![1i8; 9],
            thresholds: vec![5],
        };
        let out = conv_bin(&input, &layer, &weights);
        assert!(out.get(1, 1, 0), "centre sees 9 ≥ 5");
        assert!(!out.get(0, 0, 0), "corner sees 4 < 5");
        assert!(out.get(0, 1, 0), "edge sees 6 ≥ 5");
    }

    #[test]
    fn maxpool_or_semantics() {
        let mut t = BitTensor::zeros(4, 4, 1);
        t.set(0, 0, 0, true);
        let p = maxpool(&t, 2, 2);
        assert_eq!((p.h, p.w), (2, 2));
        assert!(p.get(0, 0, 0));
        assert!(!p.get(1, 1, 0));
    }

    #[test]
    fn conv_int_signs() {
        let mut input = IntTensor::zeros(1, 1, 2);
        input.data = vec![7, 3];
        let layer = Layer::conv("t", LayerKind::ConvInt, (1, 1, 2), 1, 1, 0, 1, None);
        let w = BinWeights { z2: 1, fanin: 2, data: vec![1, -1], thresholds: vec![4] };
        let out = conv_int(&input, &layer, &w);
        assert!(out.get(0, 0, 0), "7−3 = 4 ≥ 4");
    }

    #[test]
    fn tiny_network_forward_runs() {
        let net = tiny_bnn(8, 4, 3);
        let weights: Vec<BinWeights> = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), i as u64 + 1))
            .collect();
        let input = BitTensor::random(8, 8, 4, 9);
        let scores = forward_scores(&net, &input, &weights);
        assert_eq!(scores.len(), 3);
        assert!(scores.iter().all(|&s| s >= 0 && s <= net.layers[2].z1 as i64));
        // Determinism.
        assert_eq!(scores, forward_scores(&net, &input, &weights));
    }

    #[test]
    fn fc_bin_matches_fc_scores_thresholding() {
        let layer = Layer::fc("f", LayerKind::FcBin, 16, 4);
        let w = BinWeights::random(4, 16, 5);
        let input: Vec<bool> = (0..16).map(|i| i % 3 == 0).collect();
        let bits = fc_bin(&input, &layer, &w);
        let scores = fc_scores(&input, &layer, &w);
        for i in 0..4 {
            assert_eq!(bits[i], scores[i] >= w.thresholds[i]);
        }
    }
}

//! Calibration constants for the analytical area/power/energy model.
//!
//! Constants marked **[paper]** are taken verbatim from the paper's
//! measurements (Table I, Table II, Fig. 7, §V-A). Constants marked
//! **[fitted]** are free parameters of the memory/datapath energy model,
//! chosen once so that the end-to-end energy split reproduces the paper's
//! Table IV/V ratios (≈3.0× conv, 2.7×/2.4× all-layers); EXPERIMENTS.md
//! discusses the fit and its sensitivity. Constants marked **[derived]**
//! follow arithmetically from paper values.

/// **[paper]** Clock period, ns (Table II: 17 cy → 39 ns, 441 cy → 1014 ns).
pub const CLOCK_NS: f64 = 2.3;

/// **[derived]** Clock frequency, Hz.
pub const CLOCK_HZ: f64 = 1e9 / CLOCK_NS;

// ---------------------------------------------------------------- cells --

/// **[paper]** TULIP-PE area, µm² (Table II).
pub const PE_AREA_UM2: f64 = 1.53e3;
/// **[paper]** TULIP-PE power when fully active, mW (Table II).
pub const PE_POWER_MW: f64 = 0.12;

/// **[derived]** Energy of one fully-active PE cycle, pJ
/// (0.12 mW × 2.3 ns = 0.276 pJ).
pub const PE_CYCLE_PJ: f64 = PE_POWER_MW * CLOCK_NS;

/// **[fitted]** Per-neuron-evaluation energy, pJ. Calibrated to the paper's
/// Table IV/V energy totals (see the `pe_cycle_energy_consistent` test for
/// the documented Table II / Table IV tension): the within-PE clock gating
/// of unused neurons (§IV-E) plus VCD-level switching make the effective
/// per-event energy lower than Table II's fully-active apportionment.
pub const NEURON_EVAL_PJ: f64 = 0.03;
/// **[derived]** Per register-bit access (latch read or write), pJ.
pub const REG_BIT_PJ: f64 = 0.004;
/// **[fitted]** Leakage + clock-tree energy of a gated neuron-cycle, pJ.
pub const NEURON_GATED_PJ: f64 = 0.002;

/// **[paper]** YodaNN fully reconfigurable MAC area, µm² (Table II).
pub const MAC_AREA_UM2: f64 = 3.54e4;
/// **[paper]** YodaNN MAC power, fully active (integer datapath), mW.
pub const MAC_POWER_MW: f64 = 7.17;
/// **[derived]** Energy per fully-active MAC cycle, pJ (16.5 pJ).
pub const MAC_CYCLE_INT_PJ: f64 = MAC_POWER_MW * CLOCK_NS;
/// **[fitted]** Energy per MAC cycle in binary layers with 11/12 input bits
/// clock-gated (§V-A): 1/12 of the datapath plus non-gateable control /
/// accumulator overhead.
pub const MAC_CYCLE_BIN_PJ: f64 = MAC_CYCLE_INT_PJ * (1.0 / 12.0 + 0.09);
/// **[fitted]** Idle (fully clock-gated) MAC cycle, pJ.
pub const MAC_CYCLE_IDLE_PJ: f64 = 0.15;

/// **[derived]** TULIP's simplified integer-layer MAC (§V-C): chosen so the
/// Fig. 7 processing-area rollup closes — 256 PEs + 32 simplified MACs ≈
/// 656K µm² ⇒ (656K − 256·1.53K)/32 ≈ 8.26K µm².
pub const SIMPLE_MAC_AREA_UM2: f64 = 8.26e3;
/// **[derived]** Simplified-MAC power scaled by area ratio from the full
/// MAC (same drive/activity assumptions).
pub const SIMPLE_MAC_POWER_MW: f64 = MAC_POWER_MW * (SIMPLE_MAC_AREA_UM2 / MAC_AREA_UM2);
/// **[derived]** pJ per active simplified-MAC cycle.
pub const SIMPLE_MAC_CYCLE_PJ: f64 = SIMPLE_MAC_POWER_MW * CLOCK_NS;

// --------------------------------------------------------------- memory --

/// **[fitted]** Off-chip access energy per bit, pJ (conservative LPDDR-class
/// interface; both designs pay it per fetched pixel bit).
pub const OFFCHIP_PJ_PER_BIT: f64 = 8.0;
/// **[fitted]** Off-chip energy per *weight* bit, pJ — FC weight matrices
/// stream sequentially (burst-friendly), cheaper per bit than the
/// random-ish pixel refetch pattern.
pub const WEIGHT_OFFCHIP_PJ_PER_BIT: f64 = 3.0;
/// **[fitted]** L2 standard-cell-memory write, pJ/bit (pixel load, §IV-E).
pub const L2_WRITE_PJ_PER_BIT: f64 = 0.30;
/// **[fitted]** L2 → L1 transfer (read + write), pJ/bit.
pub const L2_TO_L1_PJ_PER_BIT: f64 = 0.22;
/// **[fitted]** L1 window-broadcast read, pJ/bit (SCM read amortized over
/// the broadcast to all processing units).
pub const L1_READ_PJ_PER_BIT: f64 = 0.08;
/// **[fitted]** Kernel shift-register buffer, pJ per bit shifted.
pub const KERNEL_SHIFT_PJ_PER_BIT: f64 = 0.03;
/// **[fitted]** Output-buffer write, pJ/bit.
pub const OUTBUF_PJ_PER_BIT: f64 = 0.10;
/// **[fitted]** XNOR product generation, pJ per product bit.
pub const XNOR_PJ_PER_BIT: f64 = 0.002;

// ------------------------------------------------------------ bandwidth --

/// **[fitted]** Off-chip interface bandwidth, bits per clock cycle. The
/// paper's absolute layer times imply a narrow (sub-Gb/s) external
/// interface — YodaNN's published evaluation is similarly I/O-bound. Fitted
/// so YodaNN's BinaryNet-CIFAR10 conv time lands near Table IV's 21.4 ms.
pub const OFFCHIP_BITS_PER_CYCLE: f64 = 3.05;
/// **[fitted]** Bits per pixel transferred for integer layers (both
/// designs are built for up-to-12-bit inputs).
pub const INT_PIXEL_BITS: u64 = 12;
/// **[fitted]** Bits per pixel for binary layers. The image buffers store
/// 12-bit words; the paper's Z-driven refetch accounting (Table III) only
/// pays off if binary pixels still occupy a full buffer slot on the
/// external interface, which is what the YodaNN memory layout does.
pub const BIN_PIXEL_BITS: u64 = 12;
/// **[fitted]** Weight-stream bandwidth for FC layers, bits/cycle.
pub const WEIGHT_BITS_PER_CYCLE: f64 = 1.0;

// -------------------------------------------------------------- buffers --

/// **[paper]** Fig. 7: image buffer (total / L1 / L2) area, µm².
pub const IMG_BUFFER_AREA_UM2: f64 = 680e3;
/// **[paper]** Fig. 7: image buffer L1 slice area, µm².
pub const IMG_BUFFER_L1_AREA_UM2: f64 = 233e3;
/// **[paper]** Fig. 7: image buffer L2 slice area, µm².
pub const IMG_BUFFER_L2_AREA_UM2: f64 = 468e3;
/// **[paper]** Fig. 7: kernel buffer area, µm².
pub const KERNEL_BUFFER_AREA_UM2: f64 = 293e3;
/// **[paper]** Fig. 7: controller area, µm².
pub const CONTROLLER_AREA_UM2: f64 = 4.52e3;
/// **[paper]** Fig. 7: die area, mm².
pub const DIE_AREA_MM2: f64 = 1.8;
/// **[paper]** Fig. 7: total processing area (PEs + MACs), µm² — the paper
/// lists 656K (TULIP) / 647K (YodaNN-equivalent floorplan).
pub const PROCESSING_AREA_TULIP_UM2: f64 = 656e3;
/// **[paper]** Fig. 7: YodaNN-equivalent processing area, µm².
pub const PROCESSING_AREA_YODANN_UM2: f64 = 647e3;
/// **[paper]** Fig. 7: average power of the full TULIP chip, mW.
pub const CHIP_POWER_MW: f64 = 23.9;

/// **[paper]** On-chip IFM capacity: both designs load 32 IFMs at a time.
pub const ONCHIP_IFMS: usize = 32;
/// **[paper]** TULIP instantiates 256 TULIP-PEs …
pub const TULIP_NUM_PES: usize = 256;
/// **[paper]** … and 32 simplified MACs; YodaNN has 32 full MACs.
pub const NUM_MACS: usize = 32;
/// **[paper]** 8 TULIP-PEs per processing unit → 32 units.
pub const PES_PER_UNIT: usize = 8;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_anchors_table2() {
        assert!((17.0 * CLOCK_NS - 39.1).abs() < 0.2, "MAC: 17 cy ≈ 39 ns");
        assert!((441.0 * CLOCK_NS - 1014.3).abs() < 0.5, "PE: 441 cy ≈ 1014 ns");
    }

    #[test]
    fn pe_cycle_energy_consistent() {
        assert!((PE_CYCLE_PJ - 0.276).abs() < 1e-9);
        // The paper's Table II (0.12 mW per PE over a node run) and its
        // Table IV (159 uJ for all of BinaryNet's conv layers) are not
        // mutually consistent: pricing every node at Table II's energy
        // overshoots Table IV by ~1.6x. We calibrate the per-event energies
        // to Table IV/V (the headline claim) — a fully-active PE cycle then
        // prices at ~50% of Table II's figure. EXPERIMENTS.md quantifies
        // this tension.
        let apportioned = 4.0 * NEURON_EVAL_PJ + 4.0 * REG_BIT_PJ;
        assert!(apportioned > 0.3 * PE_CYCLE_PJ && apportioned < 0.8 * PE_CYCLE_PJ,
            "{apportioned}");
    }

    #[test]
    fn area_ratio_table2() {
        let r = MAC_AREA_UM2 / PE_AREA_UM2;
        assert!((r - 23.18).abs() < 0.15, "Table II area ratio: {r}");
    }

    #[test]
    fn power_ratio_table2() {
        let r = MAC_POWER_MW / PE_POWER_MW;
        assert!((r - 59.75).abs() < 0.5, "Table II power ratio: {r}");
    }

    #[test]
    fn processing_area_rollup_fig7() {
        let tulip = TULIP_NUM_PES as f64 * PE_AREA_UM2 + NUM_MACS as f64 * SIMPLE_MAC_AREA_UM2;
        assert!(
            (tulip - PROCESSING_AREA_TULIP_UM2).abs() / PROCESSING_AREA_TULIP_UM2 < 0.01,
            "TULIP processing area rollup: {tulip}"
        );
    }

    #[test]
    fn binary_mac_gating_saves_order_of_magnitude() {
        // Gating 11/12 input bits leaves ~1/12 of the datapath plus
        // non-gateable control/accumulator overhead: 5-8x saving.
        assert!(MAC_CYCLE_BIN_PJ < MAC_CYCLE_INT_PJ / 5.0);
        assert!(MAC_CYCLE_BIN_PJ > MAC_CYCLE_INT_PJ / 13.0);
    }
}

//! Analytical area / power / energy model.
//!
//! The simulator counts *activity* (neuron evaluations, MAC cycles, memory
//! bits moved); this module prices it with the calibrated constants of
//! [`calib`] (paper-measured where available, fitted where the paper is
//! silent — every constant is annotated there). Energy = Σ activity ×
//! per-event energy; power = energy / wall-clock time; area = Σ instance
//! areas (Fig. 7 rollup).

pub mod calib;

use crate::metrics::MetricsRegistry;
use crate::neuron::Corner;

/// Activity counters accumulated by the coordinator for one layer (or a
/// whole network). All counts are totals across every unit in the array.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Activity {
    /// TULIP-PE neuron evaluations (non-gated neuron-cycles).
    pub pe_neuron_evals: u64,
    /// TULIP-PE gated neuron-cycles.
    pub pe_gated_neuron_cycles: u64,
    /// TULIP-PE local-register bit accesses (reads + writes).
    pub pe_reg_accesses: u64,
    /// Fully-reconfigurable MAC cycles on integer data.
    pub mac_int_cycles: u64,
    /// Fully-reconfigurable MAC cycles on binary data (11/12 bits gated).
    pub mac_bin_cycles: u64,
    /// Idle (clock-gated) MAC cycles.
    pub mac_idle_cycles: u64,
    /// Simplified-MAC active cycles (TULIP integer layers).
    pub simple_mac_cycles: u64,
    /// Pixel/activation bits fetched over the off-chip interface.
    pub offchip_bits: u64,
    /// Weight bits streamed over the off-chip interface (burst-friendly,
    /// cheaper per bit — see calib::WEIGHT_OFFCHIP_PJ_PER_BIT).
    pub offchip_weight_bits: u64,
    /// Bits written into the L2 SCM.
    pub l2_write_bits: u64,
    /// Bits moved L2 → L1.
    pub l2_to_l1_bits: u64,
    /// Bits read from L1 (window broadcasts).
    pub l1_read_bits: u64,
    /// Kernel-buffer bits shifted.
    pub kernel_shift_bits: u64,
    /// Output-buffer bits written.
    pub outbuf_bits: u64,
    /// XNOR product bits generated.
    pub xnor_bits: u64,
    /// Wall-clock cycles (for power and leakage).
    pub total_cycles: u64,
}

impl Activity {
    /// The activity of `k` identical repetitions (e.g. a batch of `k`
    /// images through the same network): every counter scales linearly, so
    /// the batched analytic model is exactly `k ×` the single-image model.
    pub fn scaled(&self, k: u64) -> Activity {
        Activity {
            pe_neuron_evals: self.pe_neuron_evals * k,
            pe_gated_neuron_cycles: self.pe_gated_neuron_cycles * k,
            pe_reg_accesses: self.pe_reg_accesses * k,
            mac_int_cycles: self.mac_int_cycles * k,
            mac_bin_cycles: self.mac_bin_cycles * k,
            mac_idle_cycles: self.mac_idle_cycles * k,
            simple_mac_cycles: self.simple_mac_cycles * k,
            offchip_bits: self.offchip_bits * k,
            offchip_weight_bits: self.offchip_weight_bits * k,
            l2_write_bits: self.l2_write_bits * k,
            l2_to_l1_bits: self.l2_to_l1_bits * k,
            l1_read_bits: self.l1_read_bits * k,
            kernel_shift_bits: self.kernel_shift_bits * k,
            outbuf_bits: self.outbuf_bits * k,
            xnor_bits: self.xnor_bits * k,
            total_cycles: self.total_cycles * k,
        }
    }

    /// Accumulate another record's counters (e.g. across layers).
    pub fn merge(&mut self, o: &Activity) {
        self.pe_neuron_evals += o.pe_neuron_evals;
        self.pe_gated_neuron_cycles += o.pe_gated_neuron_cycles;
        self.pe_reg_accesses += o.pe_reg_accesses;
        self.mac_int_cycles += o.mac_int_cycles;
        self.mac_bin_cycles += o.mac_bin_cycles;
        self.mac_idle_cycles += o.mac_idle_cycles;
        self.simple_mac_cycles += o.simple_mac_cycles;
        self.offchip_bits += o.offchip_bits;
        self.offchip_weight_bits += o.offchip_weight_bits;
        self.l2_write_bits += o.l2_write_bits;
        self.l2_to_l1_bits += o.l2_to_l1_bits;
        self.l1_read_bits += o.l1_read_bits;
        self.kernel_shift_bits += o.kernel_shift_bits;
        self.outbuf_bits += o.outbuf_bits;
        self.xnor_bits += o.xnor_bits;
        self.total_cycles += o.total_cycles;
    }
}

/// Energy breakdown in picojoules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// TULIP-PE energy (neuron evaluations, gated cycles, register bits).
    pub pe_pj: f64,
    /// MAC energy (full and simplified units).
    pub mac_pj: f64,
    /// Memory-subsystem energy (off-chip, L2/L1, kernel and output buffers).
    pub memory_pj: f64,
    /// XNOR product-array energy.
    pub xnor_pj: f64,
}

impl EnergyBreakdown {
    /// Total energy in picojoules.
    pub fn total_pj(&self) -> f64 {
        self.pe_pj + self.mac_pj + self.memory_pj + self.xnor_pj
    }

    /// Total energy in microjoules.
    pub fn total_uj(&self) -> f64 {
        self.total_pj() * 1e-6
    }

    /// Publish this breakdown into a metrics registry as gauges named
    /// `{prefix}.pe_pj`, `.mac_pj`, `.memory_pj`, `.xnor_pj` and
    /// `.total_pj` — how the energy model reports into the observability
    /// layer (the batch executor calls this per batch).
    pub fn publish_to(&self, registry: &MetricsRegistry, prefix: &str) {
        registry.gauge(&format!("{prefix}.pe_pj")).set(self.pe_pj);
        registry.gauge(&format!("{prefix}.mac_pj")).set(self.mac_pj);
        registry.gauge(&format!("{prefix}.memory_pj")).set(self.memory_pj);
        registry.gauge(&format!("{prefix}.xnor_pj")).set(self.xnor_pj);
        registry.gauge(&format!("{prefix}.total_pj")).set(self.total_pj());
    }
}

/// The pricing model (corner-aware; all tables use TT).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Process corner the constants are derated for.
    pub corner: Corner,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel { corner: Corner::TT }
    }
}

impl EnergyModel {
    /// A model at an explicit corner (default: TT).
    pub fn new(corner: Corner) -> Self {
        EnergyModel { corner }
    }

    /// Price an activity record.
    pub fn energy(&self, a: &Activity) -> EnergyBreakdown {
        use calib::*;
        let s = self.corner.power_derate(); // dynamic energy ∝ VDD²
        EnergyBreakdown {
            pe_pj: s
                * (a.pe_neuron_evals as f64 * NEURON_EVAL_PJ
                    + a.pe_gated_neuron_cycles as f64 * NEURON_GATED_PJ
                    + a.pe_reg_accesses as f64 * REG_BIT_PJ),
            mac_pj: s
                * (a.mac_int_cycles as f64 * MAC_CYCLE_INT_PJ
                    + a.mac_bin_cycles as f64 * MAC_CYCLE_BIN_PJ
                    + a.mac_idle_cycles as f64 * MAC_CYCLE_IDLE_PJ
                    + a.simple_mac_cycles as f64 * SIMPLE_MAC_CYCLE_PJ),
            memory_pj: s
                * (a.offchip_bits as f64 * OFFCHIP_PJ_PER_BIT
                    + a.offchip_weight_bits as f64 * WEIGHT_OFFCHIP_PJ_PER_BIT
                    + a.l2_write_bits as f64 * L2_WRITE_PJ_PER_BIT
                    + a.l2_to_l1_bits as f64 * L2_TO_L1_PJ_PER_BIT
                    + a.l1_read_bits as f64 * L1_READ_PJ_PER_BIT
                    + a.kernel_shift_bits as f64 * KERNEL_SHIFT_PJ_PER_BIT
                    + a.outbuf_bits as f64 * OUTBUF_PJ_PER_BIT),
            xnor_pj: s * a.xnor_bits as f64 * XNOR_PJ_PER_BIT,
        }
    }

    /// Wall-clock seconds for a cycle count at this corner (the clock is
    /// kept at the TT 2.3 ns for all paper tables; corner derating of the
    /// achievable period is reported separately by the Table I bench).
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * calib::CLOCK_NS * 1e-9
    }

    /// Average power in mW over a run.
    pub fn avg_power_mw(&self, a: &Activity) -> f64 {
        let e_pj = self.energy(a).total_pj();
        let t_s = self.seconds(a.total_cycles);
        if t_s == 0.0 {
            0.0
        } else {
            e_pj * 1e-12 / t_s * 1e3
        }
    }
}

/// Fig. 7 area rollup for either design point.
#[derive(Debug, Clone, Copy)]
pub struct AreaRollup {
    /// PE/MAC processing area, µm².
    pub processing_um2: f64,
    /// Image buffer (L1 + L2) area, µm².
    pub image_buffer_um2: f64,
    /// Kernel buffer area, µm².
    pub kernel_buffer_um2: f64,
    /// Controller area, µm².
    pub controller_um2: f64,
}

impl AreaRollup {
    /// Total die area in µm².
    pub fn total_um2(&self) -> f64 {
        self.processing_um2 + self.image_buffer_um2 + self.kernel_buffer_um2 + self.controller_um2
    }

    /// Total die area in mm².
    pub fn total_mm2(&self) -> f64 {
        self.total_um2() * 1e-6
    }
}

/// TULIP: 256 PEs + 32 simplified MACs + buffers (Fig. 7).
pub fn tulip_area() -> AreaRollup {
    use calib::*;
    AreaRollup {
        processing_um2: TULIP_NUM_PES as f64 * PE_AREA_UM2 + NUM_MACS as f64 * SIMPLE_MAC_AREA_UM2,
        image_buffer_um2: IMG_BUFFER_AREA_UM2,
        kernel_buffer_um2: KERNEL_BUFFER_AREA_UM2,
        controller_um2: CONTROLLER_AREA_UM2,
    }
}

/// YodaNN re-implemented on the same floorplan: 32 full MACs + the same
/// buffer subsystem ("uses 32 fully reconfigurable MAC units, and occupies
/// the same area as TULIP", §V-C).
///
/// Modelling note: Fig. 7 lists the processing area as 647K µm², while
/// 32 × the Table II per-MAC area (35.4K µm²) would be 1.13M µm² — the
/// Table II figure evidently includes per-unit input staging that is shared
/// at the array level. We follow Fig. 7 (the floorplan is the paper's
/// ground truth for the "same chip area" claim) and keep Table II's number
/// for the unit-level comparison only.
pub fn yodann_area() -> AreaRollup {
    use calib::*;
    AreaRollup {
        processing_um2: PROCESSING_AREA_YODANN_UM2,
        image_buffer_um2: IMG_BUFFER_AREA_UM2,
        kernel_buffer_um2: KERNEL_BUFFER_AREA_UM2,
        controller_um2: CONTROLLER_AREA_UM2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_activity_zero_energy() {
        let m = EnergyModel::default();
        let e = m.energy(&Activity::default());
        assert_eq!(e.total_pj(), 0.0);
        assert_eq!(m.avg_power_mw(&Activity::default()), 0.0);
    }

    /// A fully-active PE for 441 cycles must price close to the paper's
    /// 0.12 mW × 1014 ns = 122 pJ (Table II).
    #[test]
    fn pe_energy_anchor() {
        let m = EnergyModel::default();
        let a = Activity {
            pe_neuron_evals: 441 * 4,
            pe_reg_accesses: 441 * 4,
            total_cycles: 441,
            ..Default::default()
        };
        let e = m.energy(&a).total_pj();
        let paper = 0.12 * 1014.3; // mW × ns = pJ
        // Calibrated to Table IV/V (see calib::NEURON_EVAL_PJ): a fully
        // active PE prices at ~half of Table II's figure; the two tables
        // are mutually inconsistent by ~2x (EXPERIMENTS.md §Table II).
        assert!(e > 0.3 * paper && e < 0.8 * paper, "PE energy {e} vs paper {paper}");
        let p = m.avg_power_mw(&a);
        assert!(p > 0.03 && p < 0.12, "avg power {p} mW");
    }

    /// Table II: 17 fully-active integer MAC cycles ≈ 7.17 mW.
    #[test]
    fn mac_power_anchor() {
        let m = EnergyModel::default();
        let a = Activity { mac_int_cycles: 17, total_cycles: 17, ..Default::default() };
        let p = m.avg_power_mw(&a);
        assert!((p - 7.17).abs() < 0.01, "{p}");
    }

    #[test]
    fn corner_scaling() {
        let a = Activity { pe_neuron_evals: 1000, total_cycles: 1000, ..Default::default() };
        let tt = EnergyModel::new(Corner::TT).energy(&a).total_pj();
        let ss = EnergyModel::new(Corner::SS).energy(&a).total_pj();
        let ff = EnergyModel::new(Corner::FF).energy(&a).total_pj();
        assert!(ss < tt && tt < ff);
    }

    #[test]
    fn area_rollups_match_fig7() {
        let t = tulip_area();
        let y = yodann_area();
        // Both chips are ~1.8 mm² with the same buffers; processing areas
        // within ~2% of each other by construction (§V-C).
        assert!((t.processing_um2 - y.processing_um2).abs() / y.processing_um2 < 0.05);
        assert!((t.total_mm2() - calib::DIE_AREA_MM2).abs() / calib::DIE_AREA_MM2 < 0.15);
    }

    #[test]
    fn scaled_is_repeated_merge() {
        let a = Activity {
            pe_neuron_evals: 3,
            offchip_bits: 5,
            total_cycles: 10,
            ..Default::default()
        };
        let mut m = Activity::default();
        for _ in 0..4 {
            m.merge(&a);
        }
        assert_eq!(a.scaled(4), m);
        assert_eq!(a.scaled(1), a);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Activity { offchip_bits: 5, total_cycles: 10, ..Default::default() };
        a.merge(&Activity { offchip_bits: 7, total_cycles: 1, ..Default::default() });
        assert_eq!(a.offchip_bits, 12);
        assert_eq!(a.total_cycles, 11);
    }
}

//! The YodaNN baseline [17] — a conventional MAC-based BNN accelerator,
//! re-implemented (as the paper did, §V-A) in the same technology so the
//! comparison is fair.
//!
//! YodaNN's processing element is a **15-bit fully reconfigurable MAC**
//! supporting 3×3, 5×5 and 7×7 kernel windows with binary weights and up to
//! 12-bit inputs. For kernels with `k ≤ 5` the datapath fetches and reduces
//! **two IFMs per cycle** (2·k² products/cycle); for `k = 7` one IFM per
//! cycle. A 288-input weighted sum (3×3 × 32 IFMs) therefore takes
//! `32/2 + 1 = 17` cycles — exactly Table II's figure. For binary layers
//! the paper adds clock gating of 11 of the 12 input bits.
//!
//! TULIP's integer layers use a **simplified MAC** (§V-C): not
//! reconfigurable, 5×5/7×7 windows only, with a proportionally smaller
//! area/power footprint (constants in `energy::calib`).


/// Which MAC variant (Table II / §V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacKind {
    /// YodaNN's fully reconfigurable 15-bit MAC (3×3/5×5/7×7).
    FullReconfigurable,
    /// TULIP's simplified integer-layer MAC (5×5/7×7 only).
    Simplified,
}

/// Cycle/functional model of the MAC unit.
#[derive(Debug, Clone, Copy)]
pub struct MacUnit {
    /// Which MAC flavour this unit models.
    pub kind: MacKind,
    /// Accumulator width in bits (15 for YodaNN's MAC).
    pub acc_bits: u32,
}

/// Activity record for the energy model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MacStats {
    /// Cycles with the full integer datapath active.
    pub int_cycles: u64,
    /// Cycles with 11/12 input bits clock-gated (binary layers).
    pub bin_cycles: u64,
    /// Idle (fully gated) cycles.
    pub idle_cycles: u64,
}

impl MacStats {
    /// Accumulate another unit's counters into this one.
    pub fn merge(&mut self, o: &MacStats) {
        self.int_cycles += o.int_cycles;
        self.bin_cycles += o.bin_cycles;
        self.idle_cycles += o.idle_cycles;
    }

    /// Total cycles across all activity states.
    pub fn total(&self) -> u64 {
        self.int_cycles + self.bin_cycles + self.idle_cycles
    }
}

impl MacUnit {
    /// YodaNN's fully reconfigurable MAC.
    pub fn yodann() -> Self {
        MacUnit { kind: MacKind::FullReconfigurable, acc_bits: 15 }
    }

    /// TULIP's simplified integer-layer MAC.
    pub fn simplified() -> Self {
        MacUnit { kind: MacKind::Simplified, acc_bits: 15 }
    }

    /// Does this MAC support a `k × k` kernel window?
    pub fn supports_kernel(&self, k: usize) -> bool {
        match self.kind {
            MacKind::FullReconfigurable => matches!(k, 3 | 5 | 7),
            // §V-C: the simplified MAC supports only 5×5 and 7×7 windows; a
            // 3×3 layer is padded into the 5×5 datapath.
            MacKind::Simplified => matches!(k, 3 | 5 | 7),
        }
    }

    /// IFMs reduced per cycle for a `k × k` window (§V-C: "when the kernel
    /// size is small (k ≤ 5), the MAC units in both designs can fetch twice
    /// the number of IFMs").
    pub fn ifms_per_cycle(&self, k: usize) -> usize {
        if k <= 5 {
            2
        } else {
            1
        }
    }

    /// The effective window width the datapath computes with. The
    /// simplified MAC maps 3×3 onto its 5×5 datapath.
    pub fn datapath_k(&self, k: usize) -> usize {
        match self.kind {
            MacKind::FullReconfigurable => k,
            MacKind::Simplified => {
                if k <= 5 {
                    5.max(k)
                } else {
                    7
                }
            }
        }
    }

    /// Cycles to reduce one `k×k × ifms` window into the accumulator:
    /// `⌈ifms / ifms_per_cycle⌉ + 1` (pipeline fill/writeback).
    /// Table II anchor: `k = 3, ifms = 32` → 17 cycles.
    pub fn window_cycles(&self, k: usize, ifms: usize) -> u64 {
        assert!(self.supports_kernel(k), "unsupported kernel {k}");
        (ifms.div_ceil(self.ifms_per_cycle(k)) + 1) as u64
    }

    /// Functional weighted sum: binary weights (±1), integer activations.
    /// Saturates at the accumulator width, as the silicon would.
    pub fn weighted_sum(&self, inputs: &[i32], weights: &[i8]) -> i64 {
        assert_eq!(inputs.len(), weights.len());
        let max = (1i64 << (self.acc_bits - 1)) - 1;
        let min = -(1i64 << (self.acc_bits - 1));
        let mut acc = 0i64;
        for (&x, &w) in inputs.iter().zip(weights) {
            debug_assert!(w == 1 || w == -1, "YodaNN uses binary weights");
            acc += w as i64 * x as i64;
            acc = acc.clamp(min, max);
        }
        acc
    }

    /// Binary-layer weighted sum over {0,1} activations with ±1 weights —
    /// the same quantity TULIP's adder tree computes, so the two designs
    /// can be cross-checked bit-for-bit.
    pub fn binary_weighted_sum(&self, x: &[bool], w: &[i8]) -> i64 {
        let inputs: Vec<i32> = x.iter().map(|&b| if b { 1 } else { -1 }).collect();
        self.weighted_sum(&inputs, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neuron::function::xnor_popcount;

    /// Table II anchor: 288-input neuron (3×3 kernel, 32 IFMs) → 17 cycles.
    #[test]
    fn table2_cycle_anchor() {
        let mac = MacUnit::yodann();
        assert_eq!(mac.window_cycles(3, 32), 17);
    }

    #[test]
    fn window_cycles_by_kernel() {
        let mac = MacUnit::yodann();
        assert_eq!(mac.window_cycles(5, 32), 17);
        assert_eq!(mac.window_cycles(7, 32), 33); // one IFM per cycle
        assert_eq!(mac.window_cycles(3, 1), 2);
    }

    #[test]
    fn kernels_supported() {
        assert!(MacUnit::yodann().supports_kernel(3));
        assert!(!MacUnit::yodann().supports_kernel(4));
        assert_eq!(MacUnit::simplified().datapath_k(3), 5);
        assert_eq!(MacUnit::simplified().datapath_k(7), 7);
    }

    #[test]
    fn weighted_sum_functional() {
        let mac = MacUnit::yodann();
        assert_eq!(mac.weighted_sum(&[3, -2, 7], &[1, -1, -1]), 3 + 2 - 7);
    }

    #[test]
    fn saturation_at_15_bits() {
        let mac = MacUnit::yodann();
        let inputs = vec![2047i32; 32];
        let weights = vec![1i8; 32];
        assert_eq!(mac.weighted_sum(&inputs, &weights), (1 << 14) - 1);
        let weights_neg = vec![-1i8; 32];
        assert_eq!(mac.weighted_sum(&inputs, &weights_neg), -(1 << 14));
    }

    /// MAC and TULIP compute the same binary-layer quantity:
    /// `2·popcount(xnor) − n`.
    #[test]
    fn binary_sum_consistent_with_popcount() {
        let mac = MacUnit::yodann();
        let x = [true, false, true, true, false, true];
        let w = [1i8, -1, -1, 1, 1, 1];
        let s = mac.binary_weighted_sum(&x, &w);
        let pc = xnor_popcount(&x, &w) as i64;
        assert_eq!(s, 2 * pc - x.len() as i64);
    }

    #[test]
    fn stats_merge() {
        let mut a = MacStats { int_cycles: 1, bin_cycles: 2, idle_cycles: 3 };
        a.merge(&MacStats { int_cycles: 10, bin_cycles: 20, idle_cycles: 30 });
        assert_eq!(a.total(), 66);
    }
}

//! Property-testing loop (proptest is not available in the offline vendor
//! set). Runs a property over `cases` pseudo-random inputs with a fixed
//! seed, printing the failing case before panicking so failures reproduce.

use super::rng::Rng;

/// Default number of cases per property (matches proptest's default).
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` inputs drawn by `gen`. On failure the input's
/// `Debug` form and case index are printed, then the assertion propagates.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T),
) {
    let mut rng = Rng::seed_from_u64(0xBADC0FFEE0DDF00D ^ name.len() as u64);
    for case in 0..cases {
        let input = gen(&mut rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&input)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} with input: {input:?}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "add-commutes",
            64,
            |r| (r.gen_range_i64(-100, 100), r.gen_range_i64(-100, 100)),
            |&(a, b)| {
                assert_eq!(a + b, b + a);
            },
        );
    }

    #[test]
    #[should_panic]
    fn failing_property_panics_with_case() {
        forall("always-false", 8, |r| r.gen_index(10), |_| panic!("boom"));
    }
}

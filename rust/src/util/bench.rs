//! Micro-benchmark harness (criterion is not available in the offline
//! vendor set). Warm-up + repeated timed runs, reporting median and spread;
//! used by every `rust/benches/*.rs` target (`harness = false`).

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name as printed.
    pub name: String,
    /// Median per-iteration time across samples.
    pub median: Duration,
    /// Fastest sample's per-iteration time.
    pub min: Duration,
    /// Slowest sample's per-iteration time.
    pub max: Duration,
    /// Iterations per timed sample (auto-scaled).
    pub iters_per_sample: u64,
}

impl BenchResult {
    /// Median per-iteration time in nanoseconds.
    pub fn median_ns(&self) -> f64 {
        self.median.as_secs_f64() * 1e9
    }
}

/// Run `f` repeatedly, auto-scaling iterations so each sample takes ≥ 20 ms,
/// and report the median of `samples` samples. `f` should return something
/// observable to keep the optimizer honest (the value is black-boxed).
pub fn bench<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    // Warm-up and iteration scaling.
    let mut iters: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let dt = t0.elapsed();
        if dt >= Duration::from_millis(20) || iters >= 1 << 24 {
            break;
        }
        iters = (iters * 2).max((iters as f64 * 0.025 / dt.as_secs_f64().max(1e-9)) as u64);
    }
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples.max(3) {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        // Divide in f64 nanoseconds — Duration division truncates sub-ns
        // per-iteration times to zero for very cheap bodies.
        let per_iter_ns = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;
        times.push(Duration::from_nanos(per_iter_ns.max(0.0) as u64).max(Duration::from_nanos(
            if per_iter_ns > 0.0 && per_iter_ns < 1.0 { 1 } else { 0 },
        )));
    }
    times.sort();
    let res = BenchResult {
        name: name.to_string(),
        median: times[times.len() / 2],
        min: times[0],
        max: times[times.len() - 1],
        iters_per_sample: iters,
    };
    println!(
        "bench {:40} median {:>12.1?}  (min {:?}, max {:?}, {} iters/sample)",
        res.name, res.median, res.min, res.max, res.iters_per_sample
    );
    res
}

/// Pretty-print a paper-style table: header + rows of (label, cells).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_timing() {
        let r = bench("noop-ish", 3, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns() > 0.0);
        assert!(r.min <= r.median && r.median <= r.max);
    }

    #[test]
    fn print_table_does_not_panic() {
        print_table("t", &["a", "bb"], &[vec!["1".into(), "2".into()]]);
    }
}

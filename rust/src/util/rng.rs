//! Deterministic pseudo-random generator (SplitMix64 seeded xoshiro256**),
//! used for synthetic workloads and property tests. Statistical quality is
//! far beyond what synthetic BNN tensors need, and determinism across runs
//! and platforms is guaranteed (no `rand` version drift).

/// xoshiro256** with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator deterministically from a single `u64`.
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 to fill the state, as recommended by the xoshiro authors.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next uniform 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform boolean with probability `p` of `true`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < p
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(Rng::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn gen_bool_roughly_balanced() {
        let mut r = Rng::seed_from_u64(1);
        let ones = (0..10_000).filter(|_| r.gen_bool(0.5)).count();
        assert!((4500..5500).contains(&ones), "{ones}");
    }

    #[test]
    fn ranges_in_bounds() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..1000 {
            let v = r.gen_range_i64(-5, 9);
            assert!((-5..=9).contains(&v));
            assert!(r.gen_index(7) < 7);
        }
    }
}

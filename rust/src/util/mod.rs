//! Std-only utilities: deterministic RNG, a micro-bench harness and a
//! property-testing loop. The build environment vendors only the `xla`
//! crate's dependency set, so `rand`/`criterion`/`proptest` are replaced by
//! these small, self-contained equivalents (documented in DESIGN.md).

pub mod bench;
pub mod prop;
pub mod rng;

pub use rng::Rng;

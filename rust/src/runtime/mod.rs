//! PJRT golden-model runtime: loads the HLO-text artifacts the python
//! compile path (`python/compile/aot.py`) emits, compiles them on the PJRT
//! CPU client and executes them from rust — python never runs on the
//! request path.
//!
//! The real backend depends on the vendored `xla` crate (xla_extension
//! 0.5.1), which only exists in the offline build image, so it is gated
//! behind the `pjrt` cargo feature. Without the feature a stub with the
//! same construction/discovery surface is compiled instead: `Runtime::new`,
//! `artifact_path`, `has_artifact` work as normal, and `load` returns a
//! descriptive error. Code that manipulates `xla::Literal`s directly
//! (`tests/golden.rs`, `examples/e2e_inference.rs`) is gated on the
//! feature as well.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

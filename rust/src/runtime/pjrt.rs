//! The real PJRT backend (feature `pjrt`): loads HLO-text artifacts,
//! compiles them on the PJRT CPU client and executes them from rust.
//!
//! Interchange is **HLO text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits 64-bit instruction ids which the crate's xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md
//! and DESIGN.md).

use crate::Result;
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};

/// A loaded, compiled golden model.
pub struct GoldenModel {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact stem this model was loaded from.
    pub name: String,
}

/// The PJRT runtime: one CPU client, many compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Path of an artifact by stem, e.g. `"bnn_forward"` →
    /// `artifacts/bnn_forward.hlo.txt`.
    pub fn artifact_path(&self, stem: &str) -> PathBuf {
        self.artifacts_dir.join(format!("{stem}.hlo.txt"))
    }

    /// Is the artifact present? (Tests skip gracefully when `make
    /// artifacts` has not run.)
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.artifact_path(stem).exists()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load(&self, stem: &str) -> Result<GoldenModel> {
        let path = self.artifact_path(stem);
        if !path.exists() {
            bail!("artifact {} not found — run `make artifacts`", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        Ok(GoldenModel { exe, name: stem.to_string() })
    }
}

impl GoldenModel {
    /// Execute on literal inputs; the python side lowers with
    /// `return_tuple=True`, so the single output is a tuple that we
    /// flatten.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).context("PJRT execute")?;
        let out = result[0][0].to_literal_sync()?;
        let tuple = out.to_tuple()?;
        Ok(tuple)
    }

    /// Execute and decode a single `i32` tensor output.
    pub fn run_i32(&self, inputs: &[xla::Literal]) -> Result<Vec<i32>> {
        let outs = self.run(inputs)?;
        let first = outs.into_iter().next().context("empty output tuple")?;
        Ok(first.to_vec::<i32>()?)
    }
}

/// Build an `i32` literal of the given shape from a slice.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape/product mismatch: {dims:?} vs {}", data.len());
    let flat = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims_i64)?)
}

/// Encode a binary tensor as the `{0,1}` i32 layout the golden model uses.
pub fn literal_bits(bits: &[bool], dims: &[usize]) -> Result<xla::Literal> {
    let data: Vec<i32> = bits.iter().map(|&b| b as i32).collect();
    literal_i32(&data, dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Literal helpers round-trip shapes (no artifacts needed).
    #[test]
    fn literal_helpers() {
        let l = literal_i32(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
        assert!(literal_i32(&[1, 2], &[3]).is_err());
        let b = literal_bits(&[true, false, true, true], &[4]).unwrap();
        assert_eq!(b.to_vec::<i32>().unwrap(), vec![1, 0, 1, 1]);
    }

    /// Missing artifacts fail with a helpful message rather than a crash.
    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = Runtime::new("/nonexistent-artifacts").unwrap();
        assert!(!rt.has_artifact("nope"));
        let err = match rt.load("nope") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("make artifacts"), "{err}");
        assert!(!rt.platform().is_empty());
    }
}

//! Runtime stub compiled when the `pjrt` feature is off.
//!
//! Keeps the `runtime` surface (construction, artifact discovery, load)
//! available so the CLI and integration tests build in environments without
//! the vendored `xla` crate; anything that would actually need the PJRT
//! client reports a clear error instead. The literal-conversion helpers and
//! `GoldenModel::run*` are deliberately absent here — they are unusable
//! without `xla::Literal`, and their callers (`tests/golden.rs`,
//! `examples/e2e_inference.rs`) are gated on the feature.

use crate::Result;
use anyhow::bail;
use std::path::{Path, PathBuf};

/// A loaded, compiled golden model. Never constructed by the stub — `load`
/// always errors first — but the type keeps caller code compiling.
pub struct GoldenModel {
    /// Artifact stem this model would have been loaded from.
    pub name: String,
}

/// Artifact bookkeeping without a PJRT client.
pub struct Runtime {
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Root the runtime at an artifacts directory (always succeeds; only
    /// `load` needs the real backend).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        Ok(Runtime { artifacts_dir: artifacts_dir.as_ref().to_path_buf() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }

    /// Path of an artifact by stem, e.g. `"bnn_forward"` →
    /// `artifacts/bnn_forward.hlo.txt`.
    pub fn artifact_path(&self, stem: &str) -> PathBuf {
        self.artifacts_dir.join(format!("{stem}.hlo.txt"))
    }

    /// Is the artifact present on disk?
    pub fn has_artifact(&self, stem: &str) -> bool {
        self.artifact_path(stem).exists()
    }

    /// Always an error: a missing artifact reports the same message as the
    /// real backend; a present one reports the missing feature.
    pub fn load(&self, stem: &str) -> Result<GoldenModel> {
        let path = self.artifact_path(stem);
        if !path.exists() {
            bail!("artifact {} not found — run `make artifacts`", path.display());
        }
        bail!(
            "artifact {} present, but the PJRT runtime is unavailable: rebuild with \
             `--features pjrt` (requires the vendored `xla` crate, see rust/Cargo.toml)",
            path.display()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = Runtime::new("/nonexistent-artifacts").unwrap();
        assert!(!rt.has_artifact("nope"));
        let err = rt.load("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn present_artifact_reports_missing_feature() {
        let dir = std::env::temp_dir().join("tulip-stub-artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("present.hlo.txt"), "HloModule m {}").unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert!(rt.has_artifact("present"));
        let err = rt.load("present").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}

//! Cross-module integration tests: bit-true PE array vs functional
//! reference at layer and network scope, analytic-vs-bit-true consistency,
//! tiling/coordination invariants, and property tests over the scheduler
//! (std-only `forall` harness — proptest is unavailable offline).

use tulip::arch::unit::PeArray;
use tulip::bnn::layer::LayerKind;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{alexnet, binarynet_cifar10, reference, tiny_bnn, Layer};
use tulip::config::ArchConfig;
use tulip::coordinator::{tiling, NetworkPerf};
use tulip::pe::TulipPe;
use tulip::scheduler::adder_tree::{sum_tree, threshold_node};
use tulip::scheduler::seqgen::{OpDesc, SequenceGenerator};
use tulip::scheduler::{ops, Loc};
use tulip::sim::cycle;
use tulip::util::prop::forall;
use tulip::util::Rng;

/// Property: for arbitrary fan-in and product bits, the full threshold-node
/// program equals popcount ≥ T'.
#[test]
fn prop_threshold_node_equals_popcount_threshold() {
    forall(
        "threshold-node",
        60,
        |r| {
            let n = 1 + r.gen_index(500);
            let t = r.gen_range_i64(-2, n as i64 + 2);
            let bits: Vec<bool> = (0..n).map(|_| r.gen_bool(0.5)).collect();
            (n, t, bits)
        },
        |(n, t, bits)| {
            let prog = threshold_node(*n, *t);
            prog.schedule.validate().unwrap();
            let mut pe = TulipPe::new();
            prog.schedule.run_on(&mut pe, bits);
            let pc = bits.iter().filter(|&&b| b).count() as i64;
            assert_eq!(pe.neuron_out(prog.out_neuron), pc >= *t);
        },
    );
}

/// Property: RPO peak storage stays within the physical register file for
/// every fan-in up to the paper's 1023-input example.
#[test]
fn prop_storage_fits_registers() {
    forall(
        "storage-bound",
        40,
        |r| 2 + r.gen_index(1022),
        |&n| {
            let (_, _, alloc) = sum_tree(n);
            assert!(alloc.peak_bits() <= 64, "n={n} peak={}", alloc.peak_bits());
        },
    );
}

/// Property: the sequential comparator is exactly `x > y` for arbitrary
/// widths and values.
#[test]
fn prop_comparator_gt() {
    forall(
        "comparator",
        120,
        |r| {
            let w = 1 + r.gen_index(12);
            let x = r.gen_range_i64(0, (1 << w) - 1) as u32;
            let y = r.gen_range_i64(0, (1 << w) - 1) as u32;
            (w, x, y)
        },
        |&(w, x, y)| {
            let mut pe = TulipPe::new();
            pe.regs_mut().poke_field(0, 0, w, x);
            pe.regs_mut().poke_field(1, 0, w, y);
            let s = ops::compare_gt(
                Loc::Reg { reg: 0, lsb: 0, width: w },
                Loc::Reg { reg: 1, lsb: 0, width: w },
                ops::CMP_N,
            );
            s.run_on(&mut pe, &[]);
            assert_eq!(pe.neuron_out(ops::CMP_N), x > y, "{x} > {y} (w={w})");
        },
    );
}

/// Property: accumulation across chunks equals the total popcount — the
/// Fig. 4(c) path the coordinator uses for fan-ins beyond one tree.
#[test]
fn prop_chunked_accumulation() {
    forall(
        "chunked-acc",
        30,
        |r| {
            let chunks = 2 + r.gen_index(3);
            let per = 3 + r.gen_index(60);
            let bits: Vec<bool> = (0..chunks * per).map(|_| r.gen_bool(0.5)).collect();
            (per, bits)
        },
        |(per, bits)| {
            // Emulate the chunked flow functionally: popcount of each chunk
            // via a PE sum-tree, accumulated in software (the analytic
            // model prices the accumulate adds; numerics are chunk sums).
            let mut total = 0u32;
            for chunk in bits.chunks(*per) {
                let (sched, loc, _) = sum_tree(chunk.len());
                let mut pe = TulipPe::new();
                sched.run_on(&mut pe, chunk);
                if let Loc::Reg { reg, lsb, width } = loc {
                    total += pe.regs().peek_field(reg, lsb, width);
                } else {
                    panic!("sum not in register");
                }
            }
            assert_eq!(total as usize, bits.iter().filter(|&&b| b).count());
        },
    );
}

/// Bit-true layer conv on the PE array == functional reference, randomized
/// geometry (stride/padding/channels).
#[test]
fn prop_conv_bit_true_random_geometry() {
    forall(
        "conv-geometry",
        10,
        |r| {
            let size = 4 + r.gen_index(5);
            let c = 1 + r.gen_index(4);
            let z2 = 1 + r.gen_index(6);
            let stride = 1 + r.gen_index(2);
            let pad = r.gen_index(2);
            (size, c, z2, stride, pad, r.next_u64())
        },
        |&(size, c, z2, stride, pad, seed)| {
            if size + 2 * pad < 3 {
                return;
            }
            let layer =
                Layer::conv("t", LayerKind::ConvBin, (size, size, c), 3, stride, pad, z2, None);
            let input = BitTensor::random(size, size, c, seed);
            let weights = BinWeights::random(z2, layer.fanin(), seed ^ 0xABCD);
            let mut array = PeArray::new(1, 4);
            let mut sg = SequenceGenerator::new();
            let got = cycle::conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
            assert_eq!(got.output, reference::conv_bin(&input, &layer, &weights));
        },
    );
}

/// Whole tiny network, bit-true on the PE array == functional forward.
#[test]
fn tiny_network_bit_true_forward() {
    let net = tiny_bnn(8, 4, 3);
    let seed = 77u64;
    let input = BitTensor::random(8, 8, 4, seed);
    let weights: Vec<BinWeights> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), seed + i as u64))
        .collect();
    let expect = reference::forward_scores(&net, &input, &weights);

    let mut array = PeArray::new(2, 4);
    let mut sg = SequenceGenerator::new();
    let c1 = cycle::conv_bin_cycle(&mut array, &mut sg, &input, &net.layers[0], &weights[0]);
    let p1 = cycle::maxpool_cycle(&mut array, &mut sg, &c1.output, 2, 2);
    let c2 = cycle::conv_bin_cycle(&mut array, &mut sg, &p1.output, &net.layers[1], &weights[1]);
    let p2 = cycle::maxpool_cycle(&mut array, &mut sg, &c2.output, 2, 2);
    let (_, scores, _) =
        cycle::fc_bin_cycle(&mut array, &mut sg, &p2.output.flatten(), &net.layers[2], &weights[2]);
    assert_eq!(scores, expect);
}

/// Tiling invariants: every OFM channel is produced exactly once; batch
/// sizes never exceed the array; P·Z covers exactly z1 slabs × z2 batches.
#[test]
fn prop_tiling_covers_everything() {
    forall(
        "tiling-coverage",
        80,
        |r| {
            let z1 = 1 + r.gen_index(600);
            let z2 = 1 + r.gen_index(600);
            let k = [1, 3, 5, 7][r.gen_index(4)];
            let binary = r.gen_bool(0.5);
            (z1, z2, k, binary)
        },
        |&(z1, z2, k, binary)| {
            let kind = if binary { LayerKind::ConvBin } else { LayerKind::ConvInt };
            let layer = Layer::conv("t", kind, (8, 8, z1), k, 1, k / 2, z2, None);
            for cfg in [ArchConfig::tulip(), ArchConfig::yodann()] {
                let t = tiling(&layer, &cfg);
                assert!(t.p >= 1 && t.z >= 1);
                // Slabs cover all input channels exactly once.
                assert!(t.p * t.slab_ifms >= z1, "slab coverage");
                assert!((t.p - 1) * t.slab_ifms < z1, "no empty slab");
                // Batches cover all output channels exactly once.
                assert!(t.z * t.ofm_batch >= z2, "batch coverage");
                assert!((t.z - 1) * t.ofm_batch < z2, "no empty batch");
            }
        },
    );
}

/// Scalability (§I: "throughput can simply be increased linearly by adding
/// PEs"): doubling the PEs must not slow any binary layer down and must
/// speed up compute-bound ones.
#[test]
fn pe_scaling_monotone() {
    let net = binarynet_cifar10();
    let base = NetworkPerf::model(&net, &ArchConfig::tulip());
    let doubled = NetworkPerf::model(&net, &ArchConfig::tulip().with_pes(512));
    for (a, b) in base.layers.iter().zip(&doubled.layers) {
        assert!(b.compute_cycles <= a.compute_cycles, "{}", a.name);
    }
    assert!(doubled.conv_aggregate().cycles <= base.conv_aggregate().cycles);
}

/// Cross-arch op-count identity and Table IV/V scope arithmetic.
#[test]
fn aggregates_are_consistent() {
    for net in [binarynet_cifar10(), alexnet()] {
        let t = NetworkPerf::model(&net, &ArchConfig::tulip());
        let conv = t.conv_aggregate();
        let all = t.total_aggregate();
        assert!(all.mops > conv.mops);
        assert!(all.cycles >= conv.cycles);
        assert!((conv.mops - net.conv_mops()).abs() < 1e-6);
        assert!((all.mops - net.total_mops()).abs() < 1e-6);
    }
}

/// Failure injection: a corrupted HLO artifact must produce a clean error,
/// not a crash.
#[test]
fn corrupted_artifact_clean_error() {
    let dir = std::env::temp_dir().join("tulip-corrupt-artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule garbage {{{").unwrap();
    let rt = tulip::runtime::Runtime::new(&dir).unwrap();
    assert!(rt.load("bad").is_err());
}

/// Determinism: two full model runs give identical cycle counts and the
/// same per-layer breakdown (no hidden global state in the seqgen cache).
#[test]
fn model_runs_are_reproducible() {
    let net = alexnet();
    let a = NetworkPerf::model(&net, &ArchConfig::tulip());
    let b = NetworkPerf::model(&net, &ArchConfig::tulip());
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.total_cycles, y.total_cycles);
        assert_eq!(x.activity, y.activity);
    }
}

/// Seeds propagate: different seeds give different tensors, same seed same
/// tensor (the synthetic-workload determinism contract).
#[test]
fn synthetic_workload_determinism() {
    let mut r = Rng::seed_from_u64(1);
    let _ = r.next_u64();
    assert_eq!(BitTensor::random(6, 6, 3, 5), BitTensor::random(6, 6, 3, 5));
    assert_ne!(BitTensor::random(6, 6, 3, 5), BitTensor::random(6, 6, 3, 6));
    let w = BinWeights::random(3, 27, 9);
    assert_eq!(w.data, BinWeights::random(3, 27, 9).data);
}

/// The sequence-generator cache is shared across layers with equal node
/// descriptors (the L3 hot-path optimization): modelling AlexNet touches
/// few distinct programs.
#[test]
fn seqgen_cache_effective() {
    let mut sg = SequenceGenerator::new();
    for _ in 0..100 {
        let _ = sg.program(&OpDesc::ThresholdNode { n: 288, t_popcount: 144 });
    }
    let (hits, misses) = sg.cache_stats();
    // 2 misses: the threshold-node entry plus the shared sum-tree it is
    // built from (§Perf: thresholds share the tree plan).
    assert_eq!(misses, 2);
    assert_eq!(hits, 99);
}

//! Contracts of the batched inference engine (`coordinator::batch`):
//!
//! * **Determinism** — a batch is bit-identical whether it runs on one
//!   worker thread or many (batching never changes results);
//! * **Exact accounting** — batch cycle/activity/energy aggregates equal
//!   the sum of per-image single-run numbers;
//! * **Schedule economy** — the shared [`ProgramCache`] plans each unique
//!   layer shape once per process, and a cache hit is indistinguishable
//!   from a fresh generation;
//! * **Analytic bridge** — the batched analytic model is exactly
//!   `batch ×` the single-image `NetworkPerf` model.

use std::sync::Arc;
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::{binarynet_cifar10, tiny_bnn, Model};
use tulip::config::ArchConfig;
use tulip::coordinator::{BatchExecutor, BatchPerf, BatchRequest, NetworkPerf};
use tulip::pe::PeStats;
use tulip::scheduler::seqgen::{OpDesc, SequenceGenerator};
use tulip::scheduler::ProgramCache;

fn tiny_executor(seed: u64) -> BatchExecutor {
    let model = Model::random(tiny_bnn(8, 4, 3), seed).unwrap();
    BatchExecutor::for_model(&model).unwrap().with_array(2, 4)
}

fn tiny_images(n: u64, seed: u64) -> Vec<BitTensor> {
    (0..n).map(|i| BitTensor::random(8, 8, 4, seed + i)).collect()
}

/// Batched output is bit-identical to running the same images on a single
/// worker — scores, classes, cycles and activity all match exactly.
#[test]
fn batched_equals_sequential_bit_identical() {
    let req = BatchRequest::new(tiny_images(12, 100));
    let parallel = tiny_executor(5)
        .with_threads(4)
        .with_cache(Arc::new(ProgramCache::new()))
        .run(&req)
        .unwrap();
    let serial = tiny_executor(5)
        .with_threads(1)
        .with_cache(Arc::new(ProgramCache::new()))
        .run(&req)
        .unwrap();
    assert_eq!(parallel.images.len(), serial.images.len());
    for (p, s) in parallel.images.iter().zip(&serial.images) {
        assert_eq!(p.index, s.index);
        assert_eq!(p.scores, s.scores, "image {}", p.index);
        assert_eq!(p.class, s.class);
        assert_eq!(p.cycles, s.cycles);
        assert_eq!(p.stats, s.stats);
    }
    assert_eq!(parallel.cycles, serial.cycles);
    assert_eq!(parallel.stats, serial.stats);
    assert_eq!(parallel.activity(), serial.activity());
}

/// Repeated runs of the same executor (default thread pool, shared global
/// cache) are reproducible.
#[test]
fn repeated_parallel_runs_reproducible() {
    let exec = tiny_executor(11);
    let req = BatchRequest::new(tiny_images(8, 300));
    let a = exec.run(&req).unwrap();
    let b = exec.run(&req).unwrap();
    assert_eq!(a.classes(), b.classes());
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.stats, b.stats);
    for (x, y) in a.images.iter().zip(&b.images) {
        assert_eq!(x.scores, y.scores);
    }
}

/// Batch aggregates equal the sum of per-image single-run numbers —
/// cycles and activity exactly (u64 counters), energy to float identity.
#[test]
fn aggregates_equal_sum_of_single_runs() {
    let exec = tiny_executor(9);
    let req = BatchRequest::new(tiny_images(6, 500));
    let batch = exec.run(&req).unwrap();
    let mut cycles = 0u64;
    let mut stats = PeStats::default();
    let mut energy_pj = 0.0f64;
    for (i, img) in req.images.iter().enumerate() {
        let one = exec.run_one(i, img).unwrap();
        assert_eq!(one.scores, batch.images[i].scores, "image {i}");
        assert_eq!(one.cycles, batch.images[i].cycles);
        assert_eq!(one.stats, batch.images[i].stats);
        cycles += one.cycles;
        stats.merge(&one.stats);
        energy_pj += one.energy().total_pj();
    }
    assert_eq!(batch.cycles, cycles, "batch cycles = Σ per-image cycles");
    assert_eq!(batch.stats, stats, "batch activity = Σ per-image activity");
    let batch_pj = batch.energy().total_pj();
    assert!(
        (batch_pj - energy_pj).abs() <= 1e-9 * batch_pj.max(1.0),
        "batch energy {batch_pj} pJ vs Σ per-image {energy_pj} pJ"
    );
}

/// The shared program cache plans each unique shape once: a second batch
/// through a warm cache generates nothing new, and the miss count is
/// bounded by the number of distinct (shape, threshold) descriptors.
#[test]
fn program_cache_plans_once_per_process_shape() {
    let cache = Arc::new(ProgramCache::new());
    let req = BatchRequest::new(tiny_images(8, 700));
    // Cold pass on a single worker (builds are single-flight, so the miss
    // count would be identical under parallel cold lookups too).
    let serial = tiny_executor(3).with_cache(Arc::clone(&cache)).with_threads(1);
    serial.run(&req).unwrap();
    let (hits_warm, misses_cold) = cache.stats();
    // tiny_bnn(8,4,3): ≤ 4 + 8 + 3 distinct thresholds, ≤ 2 sum-tree
    // shapes, 1 maxpool descriptor.
    assert!(misses_cold <= 18, "unexpected distinct programs: {misses_cold}");
    assert!(hits_warm > misses_cold, "steady state must be cache hits");
    // Warm parallel pass over the same shared cache: nothing replans.
    let parallel = tiny_executor(3).with_cache(Arc::clone(&cache)).with_threads(4);
    parallel.run(&req).unwrap();
    let (_, misses_warm) = cache.stats();
    assert_eq!(misses_cold, misses_warm, "warm cache must not regenerate programs");
}

/// A cache hit returns a program equal to a fresh generation (satellite
/// guarantee: caching can never change what the PEs execute).
#[test]
fn cache_hit_equals_fresh_generation() {
    let shared = ProgramCache::global();
    let d = OpDesc::ThresholdNode { n: 72, t_popcount: 30 };
    let warm = shared.program(&d);
    let hit = shared.program(&d);
    assert!(Arc::ptr_eq(&warm, &hit), "repeat lookups share one Arc");
    let mut fresh_gen = SequenceGenerator::new();
    let fresh = fresh_gen.program(&d);
    assert_eq!(hit.schedule.words, fresh.schedule.words);
    assert_eq!(hit.schedule.ext_map, fresh.schedule.ext_map);
    assert_eq!(hit.out_neuron, fresh.out_neuron);
    assert_eq!(hit.out_loc, fresh.out_loc);
}

/// Single-flight under contention: N threads racing one cold key must
/// plan exactly once — one miss, N−1 hits, one entry, one shared `Arc` —
/// and must not deadlock (the barrier maximizes the race window).
#[test]
fn cache_contention_plans_exactly_once() {
    const THREADS: usize = 8;
    let cache = Arc::new(ProgramCache::new());
    // A large fan-in makes planning slow enough that every thread arrives
    // while the build is still in flight.
    let d = OpDesc::SumTree { n: 511 };
    let barrier = Arc::new(std::sync::Barrier::new(THREADS));
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let cache = Arc::clone(&cache);
            let d = d.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                cache.program(&d)
            })
        })
        .collect();
    let progs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let s = cache.snapshot();
    assert_eq!(s.misses, 1, "exactly one thread may run the planner");
    assert_eq!(s.hits, (THREADS - 1) as u64, "the rest wait and hit");
    assert_eq!(s.entries, 1);
    for p in &progs {
        assert!(Arc::ptr_eq(p, &progs[0]), "all threads hold the same broadcast Arc");
    }
}

/// The analytic batch model is exactly `batch ×` the single-image model:
/// same schedule objects, scaled counters, zero drift.
#[test]
fn analytic_batch_is_exact_multiple() {
    let net = binarynet_cifar10();
    let cfg = ArchConfig::tulip();
    let single = NetworkPerf::model(&net, &cfg);
    let bp = BatchPerf::model(&net, &cfg, 64);
    assert_eq!(bp.total_cycles(), 64 * single.total_aggregate().cycles);
    let mut one = tulip::energy::Activity::default();
    for l in &single.layers {
        one.merge(&l.activity);
    }
    assert_eq!(bp.activity(), one.scaled(64));
    // Power-of-two scaling is exact in f64, so energy is an identity too.
    let one_pj = tulip::energy::EnergyModel::default().energy(&one).total_pj();
    assert_eq!(bp.energy().total_pj(), 64.0 * one_pj);
}

//! Observability-layer integration tests: the per-layer/per-PE breakdowns
//! partition the engine's totals exactly, the program-cache counters match
//! forced-replan scenarios, batch runs publish consistent numbers into a
//! metrics registry, and the perf report serializes all of it.

use std::sync::Arc;
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::{tiny_bnn, Model};
use tulip::coordinator::{BatchExecutor, BatchRequest, PerfReport};
use tulip::metrics::{self, MetricsRegistry};
use tulip::pe::PeStats;
use tulip::scheduler::seqgen::SequenceGenerator;
use tulip::scheduler::ProgramCache;

fn tiny_model() -> Model {
    Model::random(tiny_bnn(8, 4, 3), 300).unwrap()
}

fn tiny_executor(cache: Arc<ProgramCache>) -> BatchExecutor {
    BatchExecutor::for_model(&tiny_model()).unwrap().with_array(1, 4).with_cache(cache)
}

/// The per-layer observability records partition the forward pass exactly:
/// Σ layer cycles == whole-network cycles and Σ layer stats == total stats.
#[test]
fn per_layer_records_partition_forward_pass() {
    let model = tiny_model();
    let input = BitTensor::random(8, 8, 4, 77);
    let mut array = tulip::arch::unit::PeArray::new(1, 4);
    let mut sg = SequenceGenerator::new();
    let f = model.forward_scalar(&mut array, &mut sg, &input);

    assert_eq!(f.layers.len(), model.network().layers.len());
    let layer_cycles: u64 = f.layers.iter().map(|l| l.cycles).sum();
    assert_eq!(layer_cycles, f.cycles, "layer cycles must sum to the network total");

    let mut summed = PeStats::default();
    for l in &f.layers {
        summed.merge(&l.stats);
    }
    assert_eq!(summed, f.stats, "layer stats must sum to the network total");

    // Per-PE records cover the same activity from the other axis.
    assert_eq!(f.per_pe.len(), 4);
    let mut by_pe = PeStats::default();
    for s in &f.per_pe {
        by_pe.merge(s);
    }
    assert_eq!(by_pe.neuron_evals, f.stats.neuron_evals);
    assert_eq!(by_pe.gated_neuron_cycles, f.stats.gated_neuron_cycles);
    assert_eq!(by_pe.reg_reads + by_pe.reg_writes, f.stats.reg_reads + f.stats.reg_writes);

    // The conv layer's record absorbs its fused pool; kinds are stable.
    assert_eq!(f.layers[0].kind, "conv+pool");
    assert!(f.layers[1..].iter().all(|l| l.kind == "fc"));
    assert!(f.layers.iter().all(|l| (0.0..=1.0).contains(&l.utilization())));
}

/// Batch aggregates partition the same way: per-layer and per-PE merges
/// across the batch reproduce the batch totals.
#[test]
fn batch_breakdowns_match_totals() {
    let exec = tiny_executor(Arc::new(ProgramCache::new()));
    let req = BatchRequest::new((0..4).map(|i| BitTensor::random(8, 8, 4, 50 + i)).collect());
    let result = exec.run(&req).unwrap();

    let per_layer = result.per_layer();
    assert_eq!(per_layer.iter().map(|l| l.cycles).sum::<u64>(), result.cycles);
    let mut stats = PeStats::default();
    for l in &per_layer {
        stats.merge(&l.stats);
    }
    assert_eq!(stats, result.stats);

    let mut by_pe = PeStats::default();
    for s in result.per_pe() {
        by_pe.merge(&s);
    }
    assert_eq!(by_pe.neuron_evals, result.stats.neuron_evals);

    // Worker accounting covers every image exactly once.
    let workers = result.worker_summaries();
    assert_eq!(workers.iter().map(|w| w.images).sum::<usize>(), req.len());
    assert!(result.images.iter().all(|img| img.host_ns > 0));
}

/// Cache counters match forced-replan scenarios: a fresh cache re-misses
/// exactly the cold-run count, a warm cache adds hits only, and planning
/// time accrues on misses alone. Single-threaded: concurrent misses of one
/// descriptor are allowed to double-count (documented on [`CacheStats`]),
/// so exact counter equality is only pinned where execution is serial.
#[test]
fn cache_counters_match_forced_replan() {
    let req = BatchRequest::new((0..2).map(|i| BitTensor::random(8, 8, 4, i)).collect());

    // Cold run on a private cache.
    let cold_cache = Arc::new(ProgramCache::new());
    let exec = tiny_executor(Arc::clone(&cold_cache)).with_threads(1);
    exec.run(&req).unwrap();
    let cold = cold_cache.snapshot();
    assert!(cold.misses > 0, "cold run must plan programs");
    assert!(cold.planning_ns > 0, "planning time must be recorded");
    assert_eq!(cold.entries, cold.misses as usize, "every cold miss inserts one program");

    // Warm re-run: same batch, same cache — no new planning.
    exec.run(&req).unwrap();
    let warm = cold_cache.snapshot();
    assert_eq!(warm.misses, cold.misses, "a warm cache must not re-plan");
    assert_eq!(warm.planning_ns, cold.planning_ns, "hits must not accrue planning time");
    assert!(warm.hits > cold.hits);
    assert!(warm.hit_rate() > cold.hit_rate());

    // Forced replan: a fresh cache misses exactly the cold count again.
    let fresh_cache = Arc::new(ProgramCache::new());
    let fresh_exec = tiny_executor(Arc::clone(&fresh_cache)).with_threads(1);
    fresh_exec.run(&req).unwrap();
    assert_eq!(fresh_cache.snapshot().misses, cold.misses, "replan count is deterministic");
    assert_eq!(fresh_cache.snapshot().entries, cold.entries);
}

/// A batch run published into a scoped registry reports exactly the
/// numbers the result itself carries.
#[test]
fn published_metrics_match_batch_result() {
    let exec = tiny_executor(Arc::new(ProgramCache::new()));
    let req = BatchRequest::new((0..3).map(|i| BitTensor::random(8, 8, 4, 20 + i)).collect());
    let result = exec.run(&req).unwrap();

    let reg = MetricsRegistry::new();
    exec.publish_to(&reg, &result);
    assert_eq!(reg.counter("batch.runs").get(), 1);
    assert_eq!(reg.counter("batch.images").get(), 3);
    assert_eq!(reg.counter("batch.sim_cycles").get(), result.cycles);
    assert_eq!(reg.counter("pe.neuron_evals").get(), result.stats.neuron_evals);
    assert_eq!(reg.gauge("pe.utilization").get(), result.stats.utilization());
    let total_pj = reg.gauge("batch.energy.total_pj").get();
    assert!((total_pj - result.energy().total_pj()).abs() < 1e-9);
    let cache = exec.cache_handle().snapshot();
    assert_eq!(reg.gauge("scheduler.cache.misses").get(), cache.misses as f64);

    // The histogram saw one sample per image.
    let snap = reg.snapshot();
    let (_, host) = snap.histograms.iter().find(|(k, _)| k == "image.host_us").unwrap();
    assert_eq!(host.count, 3);

    // Publishing twice accumulates counters but re-sets gauges.
    exec.publish_to(&reg, &result);
    assert_eq!(reg.counter("batch.images").get(), 6);
    assert_eq!(reg.gauge("pe.utilization").get(), result.stats.utilization());
}

/// The perf report freezes the batch consistently and its JSON carries the
/// per-layer/per-PE/cache sections end to end.
#[test]
fn perf_report_is_consistent_with_result() {
    let exec = tiny_executor(Arc::new(ProgramCache::new()));
    let req = BatchRequest::new((0..2).map(|i| BitTensor::random(8, 8, 4, 5 + i)).collect());
    let result = exec.run(&req).unwrap();
    let reg = MetricsRegistry::new();
    exec.publish_to(&reg, &result);
    let report = PerfReport::from_batch(&exec, &result).with_metrics(reg.snapshot());

    assert_eq!(report.batch, 2);
    assert_eq!(report.total_cycles, result.cycles);
    assert_eq!(report.layers.iter().map(|l| l.cycles).sum::<u64>(), result.cycles);
    assert_eq!(report.cache, exec.cache_handle().snapshot());

    let json = report.to_json();
    assert!(json.contains("\"schema\": \"tulip.perf_report/v1\""));
    assert!(json.contains("\"conv+pool\""));
    assert!(json.contains("\"batch.images\""), "embedded registry snapshot missing");
}

/// `HistogramSnapshot::quantile` edge cases: empty snapshots, the extreme
/// quantiles, single-bucket populations, and merged snapshots all answer
/// within the recorded min/max envelope.
#[test]
fn histogram_quantiles_handle_edge_cases() {
    let reg = MetricsRegistry::new();
    let empty = reg.histogram("q.empty").snapshot();
    assert_eq!(empty.quantile(0.5), 0, "empty histogram quantile is 0");

    let h = reg.histogram("q.filled");
    for v in [10u64, 20, 30, 40, 1000] {
        h.observe(v);
    }
    let snap = h.snapshot();
    // q=0 answers the lowest sample's bucket upper bound (10 lives in the
    // log₂ bucket [8, 15]), never below the exact recorded min.
    assert_eq!(snap.quantile(0.0), 15);
    assert_eq!(snap.quantile(1.0), snap.max, "q=1 clamps to the recorded max");
    assert!(snap.quantile(0.5) >= snap.min && snap.quantile(0.5) <= snap.max);
    // p99 of 5 samples lands in the top bucket, clamped to the exact max.
    assert_eq!(snap.quantile(0.99), 1000);

    // Every sample in one bucket: all quantiles agree up to bucket clamping.
    let one = reg.histogram("q.single");
    for _ in 0..100 {
        one.observe(42);
    }
    let snap = one.snapshot();
    for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
        assert_eq!(snap.quantile(q), 42, "single-bucket population at q={q}");
    }

    // Merging two disjoint populations spans both envelopes.
    let lo = reg.histogram("q.lo");
    let hi = reg.histogram("q.hi");
    for v in 1..=50u64 {
        lo.observe(v);
        hi.observe(v + 10_000);
    }
    let mut merged = lo.snapshot();
    merged.merge(&hi.snapshot());
    assert_eq!(merged.count, 100);
    assert_eq!(merged.quantile(0.0), 1);
    assert_eq!(merged.quantile(1.0), 10_050);
    assert!(merged.quantile(0.25) <= 50, "lower quartile stays in the low population");
    assert!(merged.quantile(0.75) > 10_000, "upper quartile reaches the high population");
}

/// Window histograms rotate per-second slices under a simulated clock:
/// observations age out of narrow windows, survive wide ones, and slice
/// reuse after a full lap of the ring discards the stale second.
#[test]
fn window_histograms_rotate_under_simulated_clock() {
    let reg = MetricsRegistry::new();
    let w = reg.window_histogram("w.rotate");
    w.observe_at(100, 10);
    w.observe_at(100, 30);
    w.observe_at(105, 500);

    let wide = w.snapshot_window_at(105, 60);
    assert_eq!(wide.count, 3, "60s window spans both seconds");
    assert_eq!(wide.sum, 540);
    assert_eq!(wide.min, 10);
    assert_eq!(wide.max, 500);

    let narrow = w.snapshot_window_at(105, 1);
    assert_eq!(narrow.count, 1, "1s window sees only the newest second");
    assert_eq!(narrow.sum, 500);

    // A snapshot taken *before* a slice's second ignores that slice.
    let before = w.snapshot_window_at(104, 60);
    assert_eq!(before.count, 2, "future seconds are excluded");
    assert_eq!(before.sum, 40);

    // 64 seconds later the ring wraps onto second 100's slice; its stale
    // samples are discarded on reuse and must not leak into the window.
    w.observe_at(164, 7);
    let lap = w.snapshot_window_at(164, 60);
    assert_eq!(lap.count, 2, "second 100 gone to slice reuse; 105 and 164 remain");
    assert_eq!(lap.sum, 507);
}

/// Without the `trace` feature spans are inert; with it they record.
#[test]
fn spans_are_noops_unless_enabled() {
    assert_eq!(metrics::trace_enabled(), cfg!(feature = "trace"));
    let _ = metrics::take_events(); // drain whatever earlier tests left
    {
        let _span = metrics::span("test.outer");
    }
    let events = metrics::take_events();
    if cfg!(feature = "trace") {
        assert!(events.iter().any(|e| e.name == "test.outer"));
    } else {
        assert!(events.is_empty(), "spans must be zero-cost no-ops by default");
    }
}

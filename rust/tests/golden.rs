//! Golden-model cross-checks: the JAX/Pallas artifacts (compiled once by
//! `make artifacts`, loaded here via PJRT) must agree **bit-for-bit** with
//! both the rust functional reference and the bit-true PE simulation.
//!
//! Tests skip gracefully (with a notice) when artifacts are absent so
//! `cargo test` works before `make artifacts`. The whole file needs the
//! real PJRT backend (and with it the vendored `xla` crate), so it is
//! compiled only with the `pjrt` feature.

#![cfg(feature = "pjrt")]

use tulip::arch::unit::PeArray;
use tulip::bnn::layer::LayerKind;
use tulip::bnn::reference;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{tiny_bnn, Layer};
use tulip::runtime::{literal_bits, literal_i32, Runtime};
use tulip::scheduler::seqgen::SequenceGenerator;
use tulip::sim::cycle;

fn runtime_or_skip() -> Option<Runtime> {
    let rt = Runtime::new("artifacts").expect("PJRT client");
    if !rt.has_artifact("tiny_bnn") {
        eprintln!("skipping golden tests: run `make artifacts` first");
        return None;
    }
    Some(rt)
}

/// Weight literals in the (z2, fanin) layout both sides share.
fn weight_literal(w: &BinWeights) -> xla::Literal {
    let data: Vec<i32> = w.data.iter().map(|&v| v as i32).collect();
    literal_i32(&data, &[w.z2, w.fanin]).unwrap()
}

fn threshold_literal(w: &BinWeights) -> xla::Literal {
    let t: Vec<i32> = w.thresholds.iter().map(|&v| v as i32).collect();
    literal_i32(&t, &[w.z2]).unwrap()
}

/// Single binary conv layer: JAX golden == rust functional reference.
#[test]
fn binconv_layer_golden_matches_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.load("binconv_layer").unwrap();
    let layer = Layer::conv("c", LayerKind::ConvBin, (16, 16, 8), 3, 1, 1, 8, None);
    for seed in [1u64, 7, 42] {
        let input = BitTensor::random(16, 16, 8, seed);
        let weights = BinWeights::random(8, layer.fanin(), seed + 100);
        let x = literal_bits(&input.data, &[16, 16, 8]).unwrap();
        let out = model
            .run_i32(&[x, weight_literal(&weights), threshold_literal(&weights)])
            .unwrap();
        let expect = reference::conv_bin(&input, &layer, &weights);
        let expect_i32: Vec<i32> = expect.data.iter().map(|&b| b as i32).collect();
        assert_eq!(out, expect_i32, "seed {seed}");
    }
}

/// FC head: JAX golden scores == rust popcount scores.
#[test]
fn fc_head_golden_matches_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.load("fc_head").unwrap();
    let layer = Layer::fc("f", LayerKind::FcBin, 256, 4);
    for seed in [3u64, 9] {
        let input: Vec<bool> = {
            let t = BitTensor::random(16, 16, 1, seed);
            t.data
        };
        let weights = BinWeights::random(4, 256, seed + 5);
        let x = literal_bits(&input, &[256]).unwrap();
        let out = model.run_i32(&[x, weight_literal(&weights)]).unwrap();
        let expect: Vec<i32> =
            reference::fc_scores(&input, &layer, &weights).iter().map(|&s| s as i32).collect();
        assert_eq!(out, expect, "seed {seed}");
    }
}

/// The full TinyBNN: golden forward == rust functional forward == bit-true
/// PE-simulated forward. Three independent implementations, one answer.
#[test]
fn tiny_bnn_three_way_agreement() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.load("tiny_bnn").unwrap();
    let net = tiny_bnn(16, 8, 4);
    let seed = 2026u64;
    let input = BitTensor::random(16, 16, 8, seed);
    let weights: Vec<BinWeights> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| BinWeights::random(l.z2, l.fanin(), seed + i as u64 + 1))
        .collect();

    // 1) JAX golden via PJRT.
    let golden = model
        .run_i32(&[
            literal_bits(&input.data, &[16, 16, 8]).unwrap(),
            weight_literal(&weights[0]),
            threshold_literal(&weights[0]),
            weight_literal(&weights[1]),
            threshold_literal(&weights[1]),
            weight_literal(&weights[2]),
        ])
        .unwrap();

    // 2) Rust functional reference.
    let reference: Vec<i32> =
        reference::forward_scores(&net, &input, &weights).iter().map(|&s| s as i32).collect();
    assert_eq!(golden, reference, "golden vs functional");

    // 3) Bit-true PE simulation (every activation through real control
    //    words on the 4-neuron PEs).
    let mut array = PeArray::new(2, 4);
    let mut sg = SequenceGenerator::new();
    let c1 = cycle::conv_bin_cycle(&mut array, &mut sg, &input, &net.layers[0], &weights[0]);
    let p1 = cycle::maxpool_cycle(&mut array, &mut sg, &c1.output, 2, 2);
    let c2 = cycle::conv_bin_cycle(&mut array, &mut sg, &p1.output, &net.layers[1], &weights[1]);
    let p2 = cycle::maxpool_cycle(&mut array, &mut sg, &c2.output, 2, 2);
    let (_, scores, _) =
        cycle::fc_bin_cycle(&mut array, &mut sg, &p2.output.flatten(), &net.layers[2], &weights[2]);
    let bit_true: Vec<i32> = scores.iter().map(|&s| s as i32).collect();
    assert_eq!(golden, bit_true, "golden vs bit-true PE simulation");
}

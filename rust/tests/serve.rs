//! Contracts of the serving front-end (`serve`), end-to-end over TCP:
//!
//! * **Bit-identity** — a response produced through the socket, the
//!   admission queue and the micro-batcher is bit-identical to calling
//!   [`BatchExecutor::run_one`] directly on the same input;
//! * **Bounded admission** — the queue refuses when full under `Reject`
//!   and never exceeds capacity under `Block`;
//! * **Deadline shedding** — expired requests are answered `shed`, counted
//!   in `serve.shed`, and never executed;
//! * **Accountable drain** — shutdown flushes in-flight requests and the
//!   final [`ServeReport`] proves `admitted == completed + shed + failed`
//!   per model and in total;
//! * **Multi-model routing** — requests carry `"model"`, each name runs on
//!   its own lane, and `load_model`/`unload_model` work over the wire.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::Model;
use tulip::coordinator::BatchExecutor;
use tulip::metrics::MetricsRegistry;
use tulip::serve::{
    pack_bits, serve, BackpressurePolicy, BoundedQueue, ServeConfig, ServeHandle, ServeRequest,
    ServeResponse, Status,
};

/// The `tiny8` demo model (8×8×4 input) on a small array — the server
/// and the oracle build it independently from the same seeds.
fn tiny8_executor() -> BatchExecutor {
    let model = Model::demo("tiny8").unwrap();
    BatchExecutor::for_model(&model).unwrap().with_array(2, 4)
}

fn boot(cfg: ServeConfig) -> ServeHandle {
    serve(vec![("tiny8".into(), Model::demo("tiny8").unwrap())], cfg).unwrap()
}

fn small_cfg(max_batch: usize, max_wait_us: u64) -> ServeConfig {
    ServeConfig::builder().max_batch(max_batch).max_wait_us(max_wait_us).array(2, 4).build()
}

fn image(id: u64) -> BitTensor {
    BitTensor::random(8, 8, 4, 9000 + id)
}

fn request_line(id: u64, deadline_ms: Option<u64>) -> String {
    let deadline = deadline_ms.map(|ms| format!(", \"deadline_ms\": {ms}")).unwrap_or_default();
    format!("{{\"id\": {id}, \"bits\": \"{}\"{deadline}}}\n", pack_bits(&image(id).data))
}

/// Send `lines` on one connection, close the write half, and read exactly
/// `expect` response lines back.
fn round_trip(addr: std::net::SocketAddr, lines: &[String], expect: usize) -> Vec<ServeResponse> {
    let mut stream = TcpStream::connect(addr).unwrap();
    for l in lines {
        stream.write_all(l.as_bytes()).unwrap();
    }
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut out = Vec::with_capacity(expect);
    for line in BufReader::new(stream).lines() {
        out.push(ServeResponse::parse(&line.unwrap()).unwrap());
        if out.len() == expect {
            break;
        }
    }
    out
}

/// Send raw lines and return the raw reply lines (for control ops whose
/// replies are not `ServeResponse` objects).
fn raw_round_trip(addr: std::net::SocketAddr, lines: &[String], expect: usize) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    for l in lines {
        stream.write_all(l.as_bytes()).unwrap();
    }
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut out = Vec::with_capacity(expect);
    for line in BufReader::new(stream).lines() {
        out.push(line.unwrap());
        if out.len() == expect {
            break;
        }
    }
    out
}

/// (a) End-to-end bit-identity: scores and class through the socket equal
/// a direct `run_one` on the same image.
#[test]
fn responses_bit_identical_to_direct_execution() {
    let handle = boot(small_cfg(4, 500));
    let oracle = tiny8_executor();
    let n = 10u64;
    let lines: Vec<String> = (0..n).map(|id| request_line(id, None)).collect();
    let mut responses = round_trip(handle.local_addr(), &lines, n as usize);
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n as usize);
    for r in &responses {
        assert_eq!(r.status, Status::Ok, "request {}: {:?}", r.id, r.error);
        let direct = oracle.run_one(0, &image(r.id)).unwrap();
        assert_eq!(r.scores, direct.scores, "request {} scores drifted through serving", r.id);
        assert_eq!(r.class, Some(direct.class));
        assert!(r.batch_n >= 1 && r.batch_n <= 4, "occupancy within max_batch");
    }
    let report = handle.drain().unwrap();
    assert_eq!(report.total.completed, n);
    assert!(report.accounted());
}

/// (b) Admission is bounded. The queue (the exact object the server runs
/// on) refuses when full under `Reject` and never exceeds capacity under
/// `Block` — producers wait instead of overfilling.
#[test]
fn admission_queue_is_bounded_under_both_policies() {
    let mk = |id: u64| {
        let (tx, _rx) = channel();
        // The receiver is intentionally dropped: this test is about
        // admission, and replies are best-effort by design.
        ServeRequest {
            id,
            flight: 0,
            image: image(id),
            deadline: None,
            enqueued: Instant::now(),
            resp: tx,
        }
    };

    // Reject: a full queue refuses immediately and counts the rejection.
    let reg = MetricsRegistry::new();
    let q = BoundedQueue::new(3, BackpressurePolicy::Reject, &reg);
    for id in 0..3 {
        q.push(mk(id)).unwrap();
    }
    assert!(q.push(mk(3)).is_err(), "push beyond capacity must be refused");
    assert_eq!(q.len(), 3, "a refused push must not grow the queue");
    assert_eq!(reg.counter("serve.admitted").get(), 3);
    assert_eq!(reg.counter("serve.rejected").get(), 1);

    // Block: 16 producers race 4 slots; the queue never exceeds capacity
    // and every producer eventually gets in as the consumer drains.
    let reg = MetricsRegistry::new();
    let q = Arc::new(BoundedQueue::new(4, BackpressurePolicy::Block, &reg));
    let producers: Vec<_> = (0..16u64)
        .map(|id| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.push(mk(id)).expect("Block admits eventually"))
        })
        .collect();
    let mut drained = 0usize;
    while drained < 16 {
        assert!(q.len() <= 4, "Block policy exceeded capacity: {}", q.len());
        drained += q.next_batch(2, Duration::from_millis(5)).len();
    }
    for p in producers {
        p.join().unwrap();
    }
    assert_eq!(reg.counter("serve.admitted").get(), 16);
    assert_eq!(reg.counter("serve.rejected").get(), 0);
}

/// (c) Expired requests are shed before execution: with a long batch wait
/// and a 1 ms deadline, both queued requests expire while the batcher is
/// topping up, are answered `shed`, counted, and never run (completed 0).
#[test]
fn expired_requests_shed_before_execution_and_counted() {
    // The 60 ms top-up window outlives the 1 ms deadlines.
    let handle = boot(small_cfg(64, 60_000));
    let lines = vec![request_line(0, Some(1)), request_line(1, Some(1))];
    let responses = round_trip(handle.local_addr(), &lines, 2);
    for r in &responses {
        assert_eq!(r.status, Status::Shed, "request {}: {:?}", r.id, r.error);
        assert!(r.error.as_deref().unwrap_or("").contains("deadline"));
    }
    let report = handle.drain().unwrap();
    assert_eq!(report.total.shed, 2, "both sheds counted in serve.shed");
    assert_eq!(report.total.completed, 0, "shed requests must never execute");
    assert!(report.accounted());
}

/// (d)+(e) Drain accounts for every admitted request with zero
/// discrepancy, and the batch-occupancy histogram is non-empty.
#[test]
fn drain_accounts_every_admitted_request() {
    let handle = boot(small_cfg(8, 300));
    let n = 24u64;
    // A mixed load: a third carries aggressive 1 ms deadlines, so the
    // final tally may split between completed and shed — the invariant
    // must hold either way.
    let lines: Vec<String> =
        (0..n).map(|id| request_line(id, (id % 3 == 0).then_some(1))).collect();
    let responses = round_trip(handle.local_addr(), &lines, n as usize);
    assert_eq!(responses.len(), n as usize, "every request answered exactly once");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>());

    let report = handle.drain().unwrap();
    let stats = &report.total;
    assert_eq!(stats.admitted, n, "all {n} requests admitted");
    assert_eq!(
        stats.admitted,
        stats.completed + stats.shed + stats.failed,
        "accounting discrepancy: admitted {} vs completed {} + shed {} + failed {}",
        stats.admitted,
        stats.completed,
        stats.shed,
        stats.failed
    );
    assert!(report.accounted());
    assert_eq!(stats.failed, 0, "no engine failures expected");
    // (e) Occupancy histogram published and non-empty under load.
    assert!(stats.occupancy.count > 0, "batch-occupancy histogram must be non-empty");
    assert!(stats.occupancy.max <= 8, "occupancy bounded by max_batch");
    assert_eq!(stats.completed, stats.occupancy.sum, "occupancy sums to completed images");
    // Latency histograms cover every completed request.
    assert_eq!(stats.total_us.count, stats.completed);
    // And the report serializes the serve section plus the per-model view.
    let json = report.to_json();
    assert!(json.contains("\"serve\""), "report JSON embeds the serve section");
    assert!(json.contains("\"models\""), "report JSON breaks out per-model reports");
    assert!(json.contains("\"batch_occupancy\""));
    // The per-model engine report saw every completed image.
    let per_model = report.model("tiny8").expect("per-model report retained");
    assert_eq!(per_model.batch as u64, stats.completed);
}

/// The wire control ops work: `{"op": "stats"}` answers with counters and
/// `{"op": "drain"}` acks, closes admission, and unblocks the handle.
#[test]
fn wire_stats_and_drain_ops() {
    let handle = boot(small_cfg(4, 300));
    let addr = handle.local_addr();
    let lines = vec![request_line(0, None)];
    let r = round_trip(addr, &lines, 1);
    assert_eq!(r[0].status, Status::Ok);

    // Stats snapshot over the wire, with the per-model breakdown.
    let line = raw_round_trip(addr, &["{\"op\": \"stats\"}\n".into()], 1).remove(0);
    assert!(line.contains("\"op\": \"stats\""), "{line}");
    assert!(line.contains("\"admitted\": 1"), "{line}");
    assert!(line.contains("\"models\""), "{line}");
    assert!(line.contains("\"tiny8\""), "{line}");

    // Drain over the wire: ack, then the handle sees the request.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"{\"op\": \"drain\"}\n").unwrap();
    let mut ack = String::new();
    BufReader::new(s.try_clone().unwrap()).read_line(&mut ack).unwrap();
    assert!(ack.contains("\"ack\": true"), "{ack}");
    handle.wait_for_drain();
    assert!(handle.drain_requested());
    let report = handle.drain().unwrap();
    assert_eq!(report.total.completed, 1);
    assert!(report.accounted());
    // New connections are refused once the server is gone.
    std::thread::sleep(Duration::from_millis(20));
    assert!(TcpStream::connect(addr).is_err(), "listener must be closed after drain");
}

/// Malformed lines are answered `error` without poisoning the connection:
/// a good request after a bad one still completes.
#[test]
fn protocol_errors_are_per_request_not_per_connection() {
    let handle = boot(small_cfg(4, 300));
    let lines = vec![
        "{\"id\": 1, \"bits\": \"zz\"}\n".to_string(), // bad payload
        "not json at all\n".to_string(),               // unparseable
        request_line(7, None),                         // still served
    ];
    let responses = round_trip(handle.local_addr(), &lines, 3);
    let ok: Vec<_> = responses.iter().filter(|r| r.status == Status::Ok).collect();
    let errors = responses.iter().filter(|r| r.status == Status::Error).count();
    assert_eq!(errors, 2, "both bad lines answered error");
    assert_eq!(ok.len(), 1);
    assert_eq!(ok[0].id, 7);
    let report = handle.drain().unwrap();
    assert_eq!(report.total.admitted, 1, "bad lines are never admitted");
    assert!(report.accounted());
}

/// Multi-model serving: two models boot, each request routes by its
/// `"model"` field to the right lane (verified bit-identically per model),
/// a third model hot-loads over the wire, serves, and unloads with zero
/// accounting discrepancy. Unknown names get typed errors, not crashes.
#[test]
fn multi_model_routing_hot_load_and_unload() {
    let tiny = Model::demo("tiny").unwrap();
    let tiny8 = Model::demo("tiny8").unwrap();
    let handle = serve(
        vec![("tiny".into(), tiny.clone()), ("tiny8".into(), tiny8.clone())],
        small_cfg(4, 300),
    )
    .unwrap();
    let addr = handle.local_addr();

    let line_for = |id: u64, model: &str, m: &Model| {
        let (h, w, c) = m.input_dims();
        let img = BitTensor::random(h, w, c, 9000 + id);
        format!(
            "{{\"id\": {id}, \"model\": \"{model}\", \"bits\": \"{}\"}}\n",
            pack_bits(&img.data)
        )
    };

    // Interleave both models on one connection; each must be answered by
    // its own lane's executor, bit-identically.
    let lines: Vec<String> = (0..8u64)
        .map(|id| {
            if id % 2 == 0 {
                line_for(id, "tiny", &tiny)
            } else {
                line_for(id, "tiny8", &tiny8)
            }
        })
        .collect();
    let mut responses = round_trip(addr, &lines, 8);
    responses.sort_by_key(|r| r.id);
    let oracle_tiny = BatchExecutor::for_model(&tiny).unwrap().with_array(2, 4);
    let oracle_tiny8 = tiny8_executor();
    for r in &responses {
        assert_eq!(r.status, Status::Ok, "request {}: {:?}", r.id, r.error);
        let (oracle, model) =
            if r.id % 2 == 0 { (&oracle_tiny, &tiny) } else { (&oracle_tiny8, &tiny8) };
        let (h, w, c) = model.input_dims();
        let direct = oracle.run_one(0, &BitTensor::random(h, w, c, 9000 + r.id)).unwrap();
        assert_eq!(r.scores, direct.scores, "request {} routed to the wrong lane?", r.id);
        assert_eq!(r.class, Some(direct.class));
    }

    // Unknown model: typed per-request error, connection stays usable.
    let bad = "{\"id\": 99, \"model\": \"nope\", \"bits\": \"00\"}\n".to_string();
    let r = round_trip(addr, &[bad], 1).remove(0);
    assert_eq!(r.status, Status::Error);
    assert!(r.error.as_deref().unwrap_or("").contains("unknown model"), "{:?}", r.error);

    // Hot-load a third model over the wire and serve from it.
    let third = Model::random(tulip::bnn::tiny_bnn(8, 4, 3), 4242).unwrap();
    let load = format!(
        "{{\"op\": \"load_model\", \"name\": \"third\", \"model\": {}}}\n",
        third.to_json()
    );
    let ack = raw_round_trip(addr, &[load.clone()], 1).remove(0);
    assert!(ack.contains("\"ok\": true"), "{ack}");
    // Loading the same name again is a typed refusal.
    let dup = raw_round_trip(addr, &[load], 1).remove(0);
    assert!(dup.contains("\"ok\": false") && dup.contains("already loaded"), "{dup}");

    let third_lines: Vec<String> = (0..4u64).map(|id| line_for(id, "third", &third)).collect();
    let mut served = round_trip(addr, &third_lines, 4);
    served.sort_by_key(|r| r.id);
    let oracle_third = BatchExecutor::for_model(&third).unwrap().with_array(2, 4);
    for r in &served {
        assert_eq!(r.status, Status::Ok, "request {}: {:?}", r.id, r.error);
        let direct = oracle_third.run_one(0, &BitTensor::random(8, 8, 4, 9000 + r.id)).unwrap();
        assert_eq!(r.scores, direct.scores);
    }

    // Unload it: the reply must prove zero accounting discrepancy.
    let unload = "{\"op\": \"unload_model\", \"name\": \"third\"}\n".to_string();
    let gone = raw_round_trip(addr, &[unload.clone()], 1).remove(0);
    assert!(gone.contains("\"ok\": true"), "{gone}");
    assert!(gone.contains("\"accounted\": true"), "{gone}");
    assert!(gone.contains("\"completed\": 4"), "{gone}");
    // Unloading twice is a typed refusal.
    let again = raw_round_trip(addr, &[unload], 1).remove(0);
    assert!(again.contains("\"ok\": false") && again.contains("unknown model"), "{again}");
    // Requests for it now fail with a per-request error.
    let after = "{\"id\": 5, \"model\": \"third\", \"bits\": \"00\"}\n".to_string();
    let r = round_trip(addr, &[after], 1).remove(0);
    assert_eq!(r.status, Status::Error);

    // Final drain still accounts for everything — including the retired
    // lane — and retains all three per-model reports.
    let report = handle.drain().unwrap();
    assert!(report.accounted());
    assert_eq!(report.models.len(), 3, "live lanes + retired lane all reported");
    assert_eq!(report.model("third").expect("retired lane report").batch, 4);
    assert_eq!(report.total.completed, 8 + 4);
}

//! Contracts of the serving front-end (`serve`), end-to-end over TCP:
//!
//! * **Bit-identity** — a response produced through the socket, the
//!   admission queue and the micro-batcher is bit-identical to calling
//!   [`BatchExecutor::run_one`] directly on the same input;
//! * **Bounded admission** — the queue refuses when full under `Reject`
//!   and never exceeds capacity under `Block`;
//! * **Deadline shedding** — expired requests are answered `shed`, counted
//!   in `serve.shed`, and never executed;
//! * **Accountable drain** — shutdown flushes in-flight requests and the
//!   final `PerfReport` proves `admitted == completed + shed + failed`
//!   with a non-empty batch-occupancy histogram.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};
use tulip::bnn::tensor::BitTensor;
use tulip::coordinator::BatchExecutor;
use tulip::metrics::MetricsRegistry;
use tulip::serve::{
    demo_network, pack_bits, serve, BackpressurePolicy, BoundedQueue, ServeConfig, ServeHandle,
    ServeRequest, ServeResponse, Status,
};

/// The `tiny8` demo model (8×8×4 input) on a small array — the server
/// and the oracle build it independently from the same seeds.
fn tiny8_executor() -> BatchExecutor {
    let (net, weights) = demo_network("tiny8").unwrap();
    BatchExecutor::new(net, weights).unwrap().with_array(2, 4)
}

fn boot(cfg: ServeConfig) -> ServeHandle {
    serve(tiny8_executor(), cfg).unwrap()
}

fn image(id: u64) -> BitTensor {
    BitTensor::random(8, 8, 4, 9000 + id)
}

fn request_line(id: u64, deadline_ms: Option<u64>) -> String {
    let deadline = deadline_ms.map(|ms| format!(", \"deadline_ms\": {ms}")).unwrap_or_default();
    format!("{{\"id\": {id}, \"bits\": \"{}\"{deadline}}}\n", pack_bits(&image(id).data))
}

/// Send `lines` on one connection, close the write half, and read exactly
/// `expect` response lines back.
fn round_trip(addr: std::net::SocketAddr, lines: &[String], expect: usize) -> Vec<ServeResponse> {
    let mut stream = TcpStream::connect(addr).unwrap();
    for l in lines {
        stream.write_all(l.as_bytes()).unwrap();
    }
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut out = Vec::with_capacity(expect);
    for line in BufReader::new(stream).lines() {
        out.push(ServeResponse::parse(&line.unwrap()).unwrap());
        if out.len() == expect {
            break;
        }
    }
    out
}

/// (a) End-to-end bit-identity: scores and class through the socket equal
/// a direct `run_one` on the same image.
#[test]
fn responses_bit_identical_to_direct_execution() {
    let handle = boot(ServeConfig { max_batch: 4, max_wait_us: 500, ..ServeConfig::default() });
    let oracle = tiny8_executor();
    let n = 10u64;
    let lines: Vec<String> = (0..n).map(|id| request_line(id, None)).collect();
    let mut responses = round_trip(handle.local_addr(), &lines, n as usize);
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n as usize);
    for r in &responses {
        assert_eq!(r.status, Status::Ok, "request {}: {:?}", r.id, r.error);
        let direct = oracle.run_one(0, &image(r.id)).unwrap();
        assert_eq!(r.scores, direct.scores, "request {} scores drifted through serving", r.id);
        assert_eq!(r.class, Some(direct.class));
        assert!(r.batch_n >= 1 && r.batch_n <= 4, "occupancy within max_batch");
    }
    let report = handle.drain().unwrap();
    let stats = report.serve.expect("drain report carries serve stats");
    assert_eq!(stats.completed, n);
    assert!(stats.accounted());
}

/// (b) Admission is bounded. The queue (the exact object the server runs
/// on) refuses when full under `Reject` and never exceeds capacity under
/// `Block` — producers wait instead of overfilling.
#[test]
fn admission_queue_is_bounded_under_both_policies() {
    let mk = |id: u64| {
        let (tx, _rx) = channel();
        // The receiver is intentionally dropped: this test is about
        // admission, and replies are best-effort by design.
        ServeRequest {
            id,
            image: image(id),
            deadline: None,
            enqueued: Instant::now(),
            resp: tx,
        }
    };

    // Reject: a full queue refuses immediately and counts the rejection.
    let reg = MetricsRegistry::new();
    let q = BoundedQueue::new(3, BackpressurePolicy::Reject, &reg);
    for id in 0..3 {
        q.push(mk(id)).unwrap();
    }
    assert!(q.push(mk(3)).is_err(), "push beyond capacity must be refused");
    assert_eq!(q.len(), 3, "a refused push must not grow the queue");
    assert_eq!(reg.counter("serve.admitted").get(), 3);
    assert_eq!(reg.counter("serve.rejected").get(), 1);

    // Block: 16 producers race 4 slots; the queue never exceeds capacity
    // and every producer eventually gets in as the consumer drains.
    let reg = MetricsRegistry::new();
    let q = Arc::new(BoundedQueue::new(4, BackpressurePolicy::Block, &reg));
    let producers: Vec<_> = (0..16u64)
        .map(|id| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.push(mk(id)).expect("Block admits eventually"))
        })
        .collect();
    let mut drained = 0usize;
    while drained < 16 {
        assert!(q.len() <= 4, "Block policy exceeded capacity: {}", q.len());
        drained += q.next_batch(2, Duration::from_millis(5)).len();
    }
    for p in producers {
        p.join().unwrap();
    }
    assert_eq!(reg.counter("serve.admitted").get(), 16);
    assert_eq!(reg.counter("serve.rejected").get(), 0);
}

/// (c) Expired requests are shed before execution: with a long batch wait
/// and a 1 ms deadline, both queued requests expire while the batcher is
/// topping up, are answered `shed`, counted, and never run (completed 0).
#[test]
fn expired_requests_shed_before_execution_and_counted() {
    let handle = boot(ServeConfig {
        max_batch: 64,
        max_wait_us: 60_000, // the top-up window outlives the deadline
        ..ServeConfig::default()
    });
    let lines = vec![request_line(0, Some(1)), request_line(1, Some(1))];
    let responses = round_trip(handle.local_addr(), &lines, 2);
    for r in &responses {
        assert_eq!(r.status, Status::Shed, "request {}: {:?}", r.id, r.error);
        assert!(r.error.as_deref().unwrap_or("").contains("deadline"));
    }
    let report = handle.drain().unwrap();
    let stats = report.serve.expect("serve stats");
    assert_eq!(stats.shed, 2, "both sheds counted in serve.shed");
    assert_eq!(stats.completed, 0, "shed requests must never execute");
    assert!(stats.accounted());
}

/// (d)+(e) Drain accounts for every admitted request with zero
/// discrepancy, and the batch-occupancy histogram is non-empty.
#[test]
fn drain_accounts_every_admitted_request() {
    let handle = boot(ServeConfig { max_batch: 8, max_wait_us: 300, ..ServeConfig::default() });
    let n = 24u64;
    // A mixed load: a third carries aggressive 1 ms deadlines, so the
    // final tally may split between completed and shed — the invariant
    // must hold either way.
    let lines: Vec<String> =
        (0..n).map(|id| request_line(id, (id % 3 == 0).then_some(1))).collect();
    let responses = round_trip(handle.local_addr(), &lines, n as usize);
    assert_eq!(responses.len(), n as usize, "every request answered exactly once");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>());

    let report = handle.drain().unwrap();
    let stats = report.serve.expect("serve stats");
    assert_eq!(stats.admitted, n, "all {n} requests admitted");
    assert_eq!(
        stats.admitted,
        stats.completed + stats.shed + stats.failed,
        "accounting discrepancy: admitted {} vs completed {} + shed {} + failed {}",
        stats.admitted,
        stats.completed,
        stats.shed,
        stats.failed
    );
    assert!(stats.accounted());
    assert_eq!(stats.failed, 0, "no engine failures expected");
    // (e) Occupancy histogram published and non-empty under load.
    assert!(stats.occupancy.count > 0, "batch-occupancy histogram must be non-empty");
    assert!(stats.occupancy.max <= 8, "occupancy bounded by max_batch");
    assert_eq!(stats.completed, stats.occupancy.sum, "occupancy sums to completed images");
    // Latency histograms cover every completed request.
    assert_eq!(stats.total_us.count, stats.completed);
    // And the report serializes the serve section.
    let json = report.to_json();
    assert!(json.contains("\"serve\""), "report JSON embeds the serve section");
    assert!(json.contains("\"batch_occupancy\""));
}

/// The wire control ops work: `{"op": "stats"}` answers with counters and
/// `{"op": "drain"}` acks, closes admission, and unblocks the handle.
#[test]
fn wire_stats_and_drain_ops() {
    let handle = boot(ServeConfig { max_batch: 4, max_wait_us: 300, ..ServeConfig::default() });
    let addr = handle.local_addr();
    let lines = vec![request_line(0, None)];
    let r = round_trip(addr, &lines, 1);
    assert_eq!(r[0].status, Status::Ok);

    // Stats snapshot over the wire.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"{\"op\": \"stats\"}\n").unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    assert!(line.contains("\"op\": \"stats\""), "{line}");
    assert!(line.contains("\"admitted\": 1"), "{line}");

    // Drain over the wire: ack, then the handle sees the request.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"{\"op\": \"drain\"}\n").unwrap();
    let mut ack = String::new();
    BufReader::new(s.try_clone().unwrap()).read_line(&mut ack).unwrap();
    assert!(ack.contains("\"ack\": true"), "{ack}");
    handle.wait_for_drain();
    assert!(handle.drain_requested());
    let report = handle.drain().unwrap();
    let stats = report.serve.expect("serve stats");
    assert_eq!(stats.completed, 1);
    assert!(stats.accounted());
    // New connections are refused once the server is gone.
    std::thread::sleep(Duration::from_millis(20));
    assert!(TcpStream::connect(addr).is_err(), "listener must be closed after drain");
}

/// Malformed lines are answered `error` without poisoning the connection:
/// a good request after a bad one still completes.
#[test]
fn protocol_errors_are_per_request_not_per_connection() {
    let handle = boot(ServeConfig { max_batch: 4, max_wait_us: 300, ..ServeConfig::default() });
    let lines = vec![
        "{\"id\": 1, \"bits\": \"zz\"}\n".to_string(), // bad payload
        "not json at all\n".to_string(),               // unparseable
        request_line(7, None),                         // still served
    ];
    let responses = round_trip(handle.local_addr(), &lines, 3);
    let ok: Vec<_> = responses.iter().filter(|r| r.status == Status::Ok).collect();
    let errors = responses.iter().filter(|r| r.status == Status::Error).count();
    assert_eq!(errors, 2, "both bad lines answered error");
    assert_eq!(ok.len(), 1);
    assert_eq!(ok[0].id, 7);
    let report = handle.drain().unwrap();
    let stats = report.serve.expect("serve stats");
    assert_eq!(stats.admitted, 1, "bad lines are never admitted");
    assert!(stats.accounted());
}

//! Contracts of the `tulip.model/v1` artifact format (`bnn::model`):
//!
//! * **Lossless round trip** — `save` → `load` reproduces the network and
//!   weights exactly, and a loaded model classifies bit-identically to the
//!   in-memory original on *both* engines (scalar and bit-sliced);
//! * **Typed failures** — wrong schema version, truncated documents,
//!   corrupt payloads and missing files surface as the matching
//!   [`tulip::Error`] variant, never a panic;
//! * **Façade invariants** — `from_parts` rejects mismatched shapes, and
//!   executors built from the same artifact agree with executors built
//!   from the same seeds.

use std::path::PathBuf;
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::{tiny_bnn, Model};
use tulip::coordinator::{BatchExecutor, BatchRequest, ForwardEngine};
use tulip::Error;

/// A scratch path unique to this test binary run.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tulip-model-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Export → load → classify: the loaded model is bit-identical to the
/// in-memory original on both engines.
#[test]
fn exported_model_classifies_bit_identically() {
    let original = Model::random(tiny_bnn(8, 4, 3), 777).unwrap();
    let path = scratch("roundtrip.model.json");
    original.save(&path).unwrap();
    let loaded = Model::load(&path).unwrap();
    assert_eq!(loaded.to_json(), original.to_json(), "artifact re-export must be stable");
    assert_eq!(loaded.input_dims(), original.input_dims());
    assert_eq!(loaded.num_classes(), original.num_classes());

    let req = BatchRequest::new((0..6).map(|i| BitTensor::random(8, 8, 4, 31 + i)).collect());
    for engine in [ForwardEngine::Scalar, ForwardEngine::BitSliced] {
        let mem = BatchExecutor::for_model(&original)
            .unwrap()
            .with_array(1, 4)
            .with_engine(engine)
            .run(&req)
            .unwrap();
        let disk = BatchExecutor::for_model(&loaded)
            .unwrap()
            .with_array(1, 4)
            .with_engine(engine)
            .run(&req)
            .unwrap();
        assert_eq!(mem.classes(), disk.classes(), "{engine:?}");
        assert_eq!(mem.cycles, disk.cycles, "{engine:?}");
        for (a, b) in mem.images.iter().zip(&disk.images) {
            assert_eq!(a.scores, b.scores, "{engine:?} image {}", a.index);
        }
    }
}

/// A future (or garbage) schema version is refused with the typed
/// `UnsupportedVersion` error carrying both strings.
#[test]
fn wrong_version_is_a_typed_error() {
    let doc = Model::demo("tiny8").unwrap().to_json().replace("/v1", "/v7");
    match Model::from_json(&doc).unwrap_err() {
        Error::UnsupportedVersion { found, expected } => {
            assert_eq!(found, "tulip.model/v7");
            assert_eq!(expected, "tulip.model/v1");
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }
}

/// Truncated and corrupted artifacts fail as `ModelFormat` — with a
/// message locating the damage — and never panic.
#[test]
fn truncated_and_corrupt_artifacts_are_typed_errors() {
    let good = Model::demo("tiny8").unwrap().to_json();

    // Truncation at every eighth byte: always a typed error, never a panic.
    for cut in (0..good.len()).step_by(8) {
        let err = Model::from_json(&good[..cut]).unwrap_err();
        assert!(
            matches!(err, Error::ModelFormat(_)),
            "cut at {cut}: expected ModelFormat, got {err:?}"
        );
    }

    // Corrupt hex in the packed signs.
    let corrupt = good.replacen("\"signs\": \"", "\"signs\": \"zz", 1);
    match Model::from_json(&corrupt).unwrap_err() {
        Error::ModelFormat(m) => assert!(m.contains("signs"), "{m}"),
        other => panic!("expected ModelFormat, got {other:?}"),
    }

    // A wrong layer kind name.
    let bad_kind = good.replacen("conv_bin", "conv_ternary", 1);
    match Model::from_json(&bad_kind).unwrap_err() {
        Error::ModelFormat(m) => assert!(m.contains("conv_ternary"), "{m}"),
        other => panic!("expected ModelFormat, got {other:?}"),
    }
}

/// A missing file is `Error::Io` with the offending path and a live
/// `source()` chain (the std error survives for callers that want it).
#[test]
fn missing_file_is_io_error_with_path() {
    let path = scratch("does-not-exist.model.json");
    match Model::load(&path).unwrap_err() {
        Error::Io { path: p, source } => {
            assert!(p.contains("does-not-exist"), "{p}");
            assert_eq!(source.kind(), std::io::ErrorKind::NotFound);
        }
        other => panic!("expected Io, got {other:?}"),
    }
    // And through the std::error::Error trait the source is reachable.
    let err = Model::load(&path).unwrap_err();
    let dyn_err: &dyn std::error::Error = &err;
    assert!(dyn_err.source().is_some(), "Io must expose its source");
}

/// `from_parts` rejects shape mismatches up front with `InvalidNetwork`,
/// so no executor can ever be built over inconsistent weights.
#[test]
fn from_parts_rejects_mismatched_weights() {
    let net = tiny_bnn(8, 4, 3);
    let good = Model::random(net.clone(), 5).unwrap();
    let mut weights = good.weights().to_vec();
    weights.pop();
    match Model::from_parts(net, weights).unwrap_err() {
        Error::InvalidNetwork(m) => assert!(m.contains("weight sets"), "{m}"),
        other => panic!("expected InvalidNetwork, got {other:?}"),
    }
}

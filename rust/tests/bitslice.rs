//! Scalar vs bit-sliced engine equivalence (the tentpole contract of the
//! lane-parallel execution path):
//!
//! * every layer executor — conv, maxpool, FC — produces bit-identical
//!   outputs, wall-clock cycles, aggregate `PeStats` and per-PE partitions
//!   across randomized shapes, including ragged tails where the pixel
//!   count or `z2` is not a multiple of 64 and degenerate thresholds whose
//!   comparison epilogues collapse to constants;
//! * whole-network `ForwardResult`s are equal field for field on the zoo
//!   networks;
//! * `BatchExecutor` produces identical batches under either engine.

use tulip::arch::unit::{PeArray, SlicedArray};
use tulip::bnn::bitpack::{LaneWeights, PackedWeights};
use tulip::bnn::layer::LayerKind;
use tulip::bnn::tensor::{BinWeights, BitTensor};
use tulip::bnn::{tiny_bnn, Layer, Model};
use tulip::coordinator::{BatchExecutor, BatchRequest, ForwardEngine};
use tulip::scheduler::seqgen::SequenceGenerator;
use tulip::sim::cycle::{
    conv_bin_cycle, conv_bin_sliced, fc_bin_cycle, fc_bin_sliced, maxpool_cycle, maxpool_sliced,
};
use tulip::util::prop::forall;

/// Paired engines sharing one program cache (as the serving engine does).
fn engines() -> (PeArray, SlicedArray, SequenceGenerator, SequenceGenerator) {
    let sg = SequenceGenerator::new();
    let sg2 = SequenceGenerator::with_cache(sg.cache());
    (PeArray::new(2, 4), SlicedArray::new(2, 4), sg, sg2)
}

/// Conv: random geometry (padding, stride, channel counts beyond the
/// 8-PE array, pixel counts far from multiples of 64) — output, cycles,
/// stats and the per-PE partition must all match.
#[test]
fn prop_conv_scalar_vs_sliced() {
    forall(
        "conv-bitslice",
        25,
        |r| {
            let h = 4 + r.gen_index(9); // 4..=12
            let w = 4 + r.gen_index(9);
            let c = 1 + r.gen_index(6); // 1..=6
            let k = if r.gen_bool(0.25) { 1 } else { 3 };
            let stride = 1 + r.gen_index(2);
            let pad = r.gen_index(k / 2 + 1);
            let z2 = 1 + r.gen_index(12); // ragged over the 8-PE array
            let seed = r.gen_index(1 << 20) as u64;
            (h, w, c, k, stride, pad, z2, seed)
        },
        |&(h, w, c, k, stride, pad, z2, seed)| {
            let layer = Layer::conv("c", LayerKind::ConvBin, (w, h, c), k, stride, pad, z2, None);
            let input = BitTensor::random(h, w, c, seed);
            let weights = BinWeights::random(z2, layer.fanin(), seed ^ 0xABCD);
            let packed = PackedWeights::pack(&weights);
            let (mut array, mut arr, mut sg, mut sg2) = engines();
            let scalar = conv_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
            let sliced = conv_bin_sliced(&mut arr, &mut sg2, &input, &layer, &weights, &packed);
            assert_eq!(sliced.output, scalar.output);
            assert_eq!(sliced.cycles, scalar.cycles);
            assert_eq!(sliced.stats, scalar.stats);
            assert_eq!(arr.per_pe_stats(), array.per_pe_stats());
        },
    );
}

/// Maxpool: overlapping and non-overlapping windows, channel counts past
/// the array width.
#[test]
fn prop_maxpool_scalar_vs_sliced() {
    forall(
        "maxpool-bitslice",
        25,
        |r| {
            let k = 2 + r.gen_index(2); // 2..=3
            let stride = 1 + r.gen_index(2);
            let h = k + r.gen_index(9);
            let w = k + r.gen_index(9);
            let c = 1 + r.gen_index(11); // ragged over the 8-PE array
            let seed = r.gen_index(1 << 20) as u64;
            (h, w, c, k, stride, seed)
        },
        |&(h, w, c, k, stride, seed)| {
            let input = BitTensor::random(h, w, c, seed);
            let (mut array, mut arr, mut sg, mut sg2) = engines();
            let scalar = maxpool_cycle(&mut array, &mut sg, &input, k, stride);
            let sliced = maxpool_sliced(&mut arr, &mut sg2, &input, k, stride);
            assert_eq!(sliced.output, scalar.output);
            assert_eq!(sliced.cycles, scalar.cycles);
            assert_eq!(sliced.stats, scalar.stats);
            assert_eq!(arr.per_pe_stats(), array.per_pe_stats());
        },
    );
}

/// FC: fan-ins and output widths crossing the 64-lane boundary, plus
/// forced degenerate thresholds (const-true / const-false epilogues).
#[test]
fn prop_fc_scalar_vs_sliced() {
    forall(
        "fc-bitslice",
        25,
        |r| {
            let z1 = 8 + r.gen_index(143); // 8..=150
            let z2 = 1 + r.gen_index(130); // crosses 64 and 128
            let seed = r.gen_index(1 << 20) as u64;
            (z1, z2, seed)
        },
        |&(z1, z2, seed)| {
            let layer = Layer::fc("f", LayerKind::FcBin, z1, z2);
            let mut weights = BinWeights::random(z2, z1, seed ^ 0x5EED);
            weights.thresholds[0] = -3; // epilogue: const-true
            weights.thresholds[z2 - 1] = z1 as i64 + 7; // epilogue: const-false
            let lanes = LaneWeights::pack(&weights);
            let input: Vec<bool> = {
                let t = BitTensor::random(1, 1, z1, seed ^ 0xF00D);
                t.data
            };
            let (mut array, mut arr, mut sg, mut sg2) = engines();
            let (sb, ss, sc) = fc_bin_cycle(&mut array, &mut sg, &input, &layer, &weights);
            let (lb, ls, lc) = fc_bin_sliced(&mut arr, &mut sg2, &input, &layer, &weights, &lanes);
            assert_eq!(lb, sb);
            assert_eq!(ls, ss);
            assert_eq!(lc, sc);
            assert_eq!(arr.stats(), array.stats());
            assert_eq!(arr.per_pe_stats(), array.per_pe_stats());
        },
    );
}

/// Whole-network forward passes are equal field for field on the zoo
/// networks (conv + fused pool + FC stack; 16×16 has 256 pixels = exactly
/// four lane words, 8×8 leaves ragged groups everywhere).
#[test]
fn forward_results_identical_on_zoo_networks() {
    for (net, seed) in [(tiny_bnn(8, 4, 3), 90u64), (tiny_bnn(16, 8, 5), 400u64)] {
        let model = Model::random(net, seed).unwrap();
        let name = model.name().to_string();
        let (h, w, c) = model.input_dims();
        let input = BitTensor::random(h, w, c, seed + 17);
        let (mut array, mut arr, mut sg, mut sg2) = engines();
        let a = model.forward_scalar(&mut array, &mut sg, &input);
        let b = model.forward_sliced(&mut arr, &mut sg2, &input);
        assert_eq!(b.scores, a.scores, "{name}");
        assert_eq!(b.cycles, a.cycles, "{name}");
        assert_eq!(b.stats, a.stats, "{name}");
        assert_eq!(b.layers, a.layers, "{name}");
        assert_eq!(b.per_pe, a.per_pe, "{name}");
        // The per-layer records still partition the totals exactly.
        let layer_cycles: u64 = b.layers.iter().map(|l| l.cycles).sum();
        assert_eq!(layer_cycles, b.cycles, "{name}");
    }
}

/// The serving layer is engine-agnostic: batches are bit-identical under
/// either engine, per image and in aggregate.
#[test]
fn batch_executor_engines_agree() {
    let model = Model::random(tiny_bnn(8, 4, 3), 300).unwrap();
    let scalar = BatchExecutor::for_model(&model)
        .unwrap()
        .with_array(2, 4)
        .with_engine(ForwardEngine::Scalar);
    let sliced = BatchExecutor::for_model(&model).unwrap().with_array(2, 4);
    assert_eq!(sliced.engine(), ForwardEngine::BitSliced);
    let req = BatchRequest::new((0..4).map(|i| BitTensor::random(8, 8, 4, 700 + i)).collect());
    let a = scalar.run(&req).unwrap();
    let b = sliced.run(&req).unwrap();
    assert_eq!(a.classes(), b.classes());
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.per_pe(), b.per_pe());
    for (x, y) in a.images.iter().zip(&b.images) {
        assert_eq!(x.scores, y.scores, "image {}", x.index);
        assert_eq!(x.layers, y.layers, "image {}", x.index);
    }
}

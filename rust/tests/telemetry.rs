//! Live-telemetry contracts, end-to-end over real sockets:
//!
//! * **Scrapeable under load** — `--metrics-addr` serves a Prometheus
//!   exposition that passes the strict in-repo checker mid-traffic, with
//!   per-lane (`model="…"`) rolling-latency series and the
//!   `energy_per_classification` gauge present;
//! * **Cardinality retires with the lane** — after `unload_model`, the
//!   retired lane's labeled series vanish from the next scrape;
//! * **Flight chains are complete** — `{"op": "trace_dump"}` returns a
//!   `tulip.trace/v1` document in which every `ok` response has an
//!   admit→…→respond chain, and its Chrome conversion is valid
//!   `trace_event` JSON;
//! * **Endpoint lifecycle** — `/healthz` and `/readyz` answer while
//!   serving, and the endpoint dies with the server's drain.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use tulip::bnn::tensor::BitTensor;
use tulip::bnn::Model;
use tulip::metrics::flight::{self, FlightStage};
use tulip::metrics::{check_exposition, FlightDump};
use tulip::serve::protocol::{parse_json, Json};
use tulip::serve::{pack_bits, serve, ServeConfig, ServeHandle, ServeResponse, Status};

/// Boot a two-lane server with the telemetry endpoint on an ephemeral
/// port. Lane names are unique per test so parallel tests never share
/// flight-recorder lanes.
fn boot(lane_a: &str, lane_b: &str) -> ServeHandle {
    let cfg = ServeConfig::builder()
        .max_batch(4)
        .max_wait_us(300)
        .array(2, 4)
        .metrics_addr("127.0.0.1:0")
        .build();
    serve(
        vec![
            (lane_a.into(), Model::demo("tiny").unwrap()),
            (lane_b.into(), Model::demo("tiny8").unwrap()),
        ],
        cfg,
    )
    .unwrap()
}

fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let (head, body) = buf.split_once("\r\n\r\n").expect("complete HTTP response");
    (head.to_string(), body.to_string())
}

fn infer_line(id: u64, lane: &str, model: &Model) -> String {
    let (h, w, c) = model.input_dims();
    let img = BitTensor::random(h, w, c, 7000 + id);
    format!("{{\"id\": {id}, \"model\": \"{lane}\", \"bits\": \"{}\"}}\n", pack_bits(&img.data))
}

/// Send `lines` on one connection and read exactly `expect` replies.
fn round_trip(addr: SocketAddr, lines: &[String], expect: usize) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    for l in lines {
        stream.write_all(l.as_bytes()).unwrap();
    }
    stream.flush().unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut out = Vec::with_capacity(expect);
    for line in BufReader::new(stream).lines() {
        out.push(line.unwrap());
        if out.len() == expect {
            break;
        }
    }
    out
}

#[test]
fn metrics_scrape_is_valid_labeled_and_retires_with_lanes() {
    let handle = boot("m.tiny", "m.tiny8");
    let maddr = handle.metrics_addr().expect("metrics_addr configured");

    let (head, body) = http_get(maddr, "/healthz");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert_eq!(body, "ok\n");
    let (head, _) = http_get(maddr, "/readyz");
    assert!(head.starts_with("HTTP/1.1 200"), "lanes are published: {head}");

    // Traffic on both lanes so per-lane series have samples.
    let tiny = Model::demo("tiny").unwrap();
    let tiny8 = Model::demo("tiny8").unwrap();
    let lines: Vec<String> = (0..6u64)
        .map(|id| {
            if id % 2 == 0 {
                infer_line(id, "m.tiny", &tiny)
            } else {
                infer_line(id, "m.tiny8", &tiny8)
            }
        })
        .collect();
    for reply in round_trip(handle.local_addr(), &lines, 6) {
        assert_eq!(ServeResponse::parse(&reply).unwrap().status, Status::Ok, "{reply}");
    }

    let (head, body) = http_get(maddr, "/metrics");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");
    let stats = check_exposition(&body).expect("mid-load scrape passes the checker");
    assert!(stats.families > 0 && stats.samples > 0);
    // Per-lane accounting and rolling-latency series.
    assert!(stats.has_series("tulip_serve_admitted_total{model=\"m.tiny\"} 3"), "{body}");
    assert!(stats.has_series("tulip_serve_completed_total{model=\"m.tiny8\"} 3"), "{body}");
    assert!(
        stats.has_series("tulip_serve_latency_us_total_rolling{model=\"m.tiny\",window=\"10s\""),
        "{body}"
    );
    assert!(
        stats.has_series("tulip_serve_latency_us_queue_rolling{model=\"m.tiny8\",window=\"60s\""),
        "{body}"
    );
    // The engine's analytic energy gauge flows into each lane's scope.
    let energy = "tulip_batch_energy_per_classification_pj{model=\"m.tiny\"}";
    assert!(stats.has_series(energy), "{body}");
    // Engine histograms render completely (checker enforced; spot-check).
    assert!(stats.has_series("tulip_serve_latency_us_total_bucket{model=\"m.tiny\""), "{body}");

    // Retire a lane over the wire: its labeled series must vanish.
    let unload = "{\"op\": \"unload_model\", \"name\": \"m.tiny8\"}\n".to_string();
    let gone = round_trip(handle.local_addr(), &[unload], 1).remove(0);
    assert!(gone.contains("\"ok\": true") && gone.contains("\"accounted\": true"), "{gone}");
    let (_, body) = http_get(maddr, "/metrics");
    let stats = check_exposition(&body).unwrap();
    assert!(!body.contains("model=\"m.tiny8\""), "retired lane still exposed:\n{body}");
    assert!(stats.has_series("tulip_serve_admitted_total{model=\"m.tiny\"}"), "{body}");

    // Drain kills the endpoint with the server.
    let report = handle.drain().unwrap();
    assert!(report.accounted());
    std::thread::sleep(std::time::Duration::from_millis(30));
    assert!(TcpStream::connect(maddr).is_err(), "telemetry endpoint must die with the server");
}

#[test]
fn trace_dump_has_complete_chains_and_chrome_conversion() {
    let handle = boot("t.tiny", "t.tiny8");
    let maddr = handle.metrics_addr().unwrap();
    let tiny = Model::demo("tiny").unwrap();
    let lines: Vec<String> = (0..5u64).map(|id| infer_line(id, "t.tiny", &tiny)).collect();
    let ok_ids: Vec<u64> = round_trip(handle.local_addr(), &lines, 5)
        .iter()
        .map(|l| {
            let r = ServeResponse::parse(l).unwrap();
            assert_eq!(r.status, Status::Ok, "{l}");
            r.id
        })
        .collect();

    // The batcher records Respond just after handing the reply to the
    // connection writer — give the recorder a beat before dumping.
    std::thread::sleep(std::time::Duration::from_millis(50));

    // The wire op and the HTTP endpoint serve the same schema.
    let wire = round_trip(handle.local_addr(), &["{\"op\": \"trace_dump\"}\n".into()], 1).remove(0);
    let dump = FlightDump::parse(&wire).expect("wire dump parses");
    let (_, http_body) = http_get(maddr, "/trace");
    assert!(http_body.contains("\"schema\": \"tulip.trace/v1\""), "{http_body}");
    FlightDump::parse(http_body.trim()).expect("HTTP dump parses");

    // Every ok response has a complete admit→…→respond chain on its lane
    // (the test ring is far from wrapping, so nothing was dropped).
    let lane = flight::lane_id("t.tiny");
    for id in &ok_ids {
        let stages: Vec<FlightStage> = dump
            .events
            .iter()
            .filter(|e| e.request == *id && e.lane == lane)
            .map(|e| e.stage)
            .collect();
        let chain = [
            FlightStage::Admit,
            FlightStage::Dequeue,
            FlightStage::BatchSeal,
            FlightStage::Execute,
            FlightStage::Respond,
        ];
        for want in chain {
            assert!(stages.contains(&want), "request {id} missing {want:?} in {stages:?}");
        }
        let order: Vec<FlightStage> =
            stages.iter().copied().filter(|s| *s != FlightStage::Shed).collect();
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(order, sorted, "request {id} stages out of order");
    }
    // Executed requests carry a sealed micro-batch id.
    assert!(
        dump.events
            .iter()
            .any(|e| e.lane == lane && e.stage == FlightStage::Execute && e.batch > 0),
        "execute events must carry a batch id"
    );

    // Chrome conversion is valid trace_event JSON with spans for our lane.
    let chrome = dump.chrome_trace();
    let v = parse_json(&chrome).expect("chrome trace is valid JSON");
    let events = match v.get("traceEvents") {
        Some(Json::Arr(items)) => items.clone(),
        other => panic!("expected traceEvents array, got {other:?}"),
    };
    assert!(
        events
            .iter()
            .any(|e| e.get("ph").and_then(Json::as_str) == Some("X")
                && e.get("pid").and_then(Json::as_u64) == Some(lane)),
        "no complete-span events for lane {lane} in {chrome}"
    );

    let report = handle.drain().unwrap();
    assert!(report.accounted());
}

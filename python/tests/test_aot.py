"""AOT path: every registered artifact lowers to parseable HLO text with
the expected entry signature."""

import os
import tempfile

import pytest

from compile import aot, model


@pytest.mark.parametrize("stem", sorted(aot.ARTIFACTS))
def test_emit_artifact(stem):
    with tempfile.TemporaryDirectory() as d:
        path = aot.emit(stem, d)
        assert os.path.exists(path)
        text = open(path).read()
        # HLO text markers the rust-side parser requires.
        assert text.lstrip().startswith("HloModule"), text[:80]
        assert "ENTRY" in text
        # return_tuple=True: the root is a tuple.
        assert "tuple(" in text or "ROOT" in text
        assert len(text) > 500


def test_artifact_registry_matches_specs():
    fn, specs = aot.ARTIFACTS["tiny_bnn"]
    assert fn is model.tiny_bnn_forward
    assert len(specs) == 6
    assert specs[0].shape == (16, 16, 8)
    assert aot.ARTIFACTS["fc_head"][1][1].shape == (4, 256)


def test_hlo_text_has_expected_parameters():
    """The tiny_bnn entry takes 6 parameters (x, w1, t1, w2, t2, w3)."""
    with tempfile.TemporaryDirectory() as d:
        path = aot.emit("tiny_bnn", d)
        text = open(path).read()
        # Count distinct parameter declarations in the ENTRY computation.
        entry = text[text.index("ENTRY") :]
        first_block = entry[: entry.index("\n}")] if "\n}" in entry else entry
        n_params = first_block.count("parameter(")
        assert n_params == 6, f"expected 6 ENTRY parameters, found {n_params}"

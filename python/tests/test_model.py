"""L2 correctness: golden-model layers vs direct NumPy references (shapes,
windows ordering, end-to-end forward)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model


def np_conv_bin(x, w, t, k=3, pad=1):
    """Direct nested-loop reference of the binary conv layer (HWC, zero
    pad, (ky, kx, c) fanin order — mirrors rust/src/bnn/reference.rs)."""
    h, wd, c = x.shape
    z2 = w.shape[0]
    oh, ow = h + 2 * pad - k + 1, wd + 2 * pad - k + 1
    xp = np.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    out = np.zeros((oh, ow, z2), np.int32)
    for oy in range(oh):
        for ox in range(ow):
            win = xp[oy : oy + k, ox : ox + k, :].reshape(-1)  # (ky,kx,c)
            signed = (2 * win - 1) @ w.T.astype(np.int64).reshape(-1, z2)
            pc = (signed + k * k * c) // 2
            out[oy, ox] = (pc >= t).astype(np.int32)
    return out


def test_im2col_window_order():
    """Window flattening must be (ky, kx, c) — the order the rust scheduler
    streams products in."""
    x = jnp.arange(2 * 2 * 3, dtype=jnp.int32).reshape(2, 2, 3)
    cols = model.im2col(x, k=3, stride=1, pad=1)
    assert cols.shape == (4, 27)
    # Window at output (0,0): centre element (ky=1,kx=1) is input (0,0).
    w00 = np.asarray(cols[0]).reshape(3, 3, 3)
    np.testing.assert_array_equal(w00[1, 1], np.asarray(x[0, 0]))
    # Top-left of that window is padding.
    np.testing.assert_array_equal(w00[0, 0], np.zeros(3))


@settings(max_examples=8, deadline=None)
@given(
    size=st.sampled_from([4, 6, 8]),
    c=st.integers(1, 4),
    z2=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_layer_matches_loop_reference(size, c, z2, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, size=(size, size, c)).astype(np.int32)
    w = (rng.integers(0, 2, size=(z2, 9 * c)) * 2 - 1).astype(np.int32)
    t = rng.integers(0, 9 * c + 1, size=(z2,)).astype(np.int32)
    got = model.conv_bin_layer(jnp.asarray(x), jnp.asarray(w), jnp.asarray(t))
    want = np_conv_bin(x, w, t)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_maxpool_layer_or_semantics():
    x = np.zeros((4, 4, 2), np.int32)
    x[0, 0, 0] = 1
    x[3, 3, 1] = 1
    got = np.asarray(model.maxpool_layer(jnp.asarray(x)))
    assert got.shape == (2, 2, 2)
    assert got[0, 0, 0] == 1 and got[1, 1, 1] == 1
    assert got.sum() == 2


def test_fc_scores_popcount():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 2, size=(16,)).astype(np.int32)
    w = (rng.integers(0, 2, size=(3, 16)) * 2 - 1).astype(np.int32)
    got = np.asarray(model.fc_scores(jnp.asarray(x), jnp.asarray(w)))
    want = np.array(
        [np.sum(x == (w[i] > 0).astype(np.int32)) for i in range(3)], np.int32
    )
    np.testing.assert_array_equal(got, want)


def test_tiny_bnn_forward_shapes_and_determinism():
    specs = model.tiny_bnn_specs(size=16, ch=8, classes=4)
    rng = np.random.default_rng(42)
    args = []
    for s in specs:
        if len(s.shape) == 2 and s.shape[1] > 16:  # weights
            args.append(jnp.asarray(rng.integers(0, 2, size=s.shape) * 2 - 1, jnp.int32))
        elif len(s.shape) == 1:  # thresholds
            args.append(jnp.asarray(rng.integers(0, 72, size=s.shape), jnp.int32))
        else:  # input
            args.append(jnp.asarray(rng.integers(0, 2, size=s.shape), jnp.int32))
    scores = model.tiny_bnn_forward(*args)
    assert scores.shape == (4,)
    assert (np.asarray(scores) >= 0).all()
    assert (np.asarray(scores) <= 256).all()
    np.testing.assert_array_equal(
        np.asarray(scores), np.asarray(model.tiny_bnn_forward(*args))
    )


def test_fc_bin_thresholded():
    rng = np.random.default_rng(2)
    x = rng.integers(0, 2, size=(32,)).astype(np.int32)
    w = (rng.integers(0, 2, size=(5, 32)) * 2 - 1).astype(np.int32)
    t = rng.integers(0, 33, size=(5,)).astype(np.int32)
    got = np.asarray(model.fc_bin(jnp.asarray(x), jnp.asarray(w), jnp.asarray(t)))
    pc = np.array([np.sum(x == (w[i] > 0)) for i in range(5)])
    np.testing.assert_array_equal(got, (pc >= t).astype(np.int32))

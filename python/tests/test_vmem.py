"""§Perf L1 structural estimates: the numbers EXPERIMENTS.md cites must be
reproducible from the estimator, and the shipped tiling must satisfy the
design targets (fits VMEM with double-buffering headroom; compute-bound at
BNN-layer shapes; full MXU occupancy)."""

from compile.kernels.vmem import KernelEstimate, default_estimate, report


def test_default_tiling_fits_vmem_with_headroom():
    est = default_estimate(4)
    # 3 tiles of 64 KiB each at int32.
    assert est.tile_bytes == 3 * 128 * 128 * 4
    assert est.vmem_fraction < 0.05, est.vmem_fraction


def test_full_mxu_occupancy_at_default_tiling():
    assert default_estimate().mxu_utilization() == 1.0
    # Narrow blocks under-occupy the systolic array.
    assert KernelEstimate(32, 32, 128, 1).mxu_utilization() == (32 / 128) ** 2


def test_roofline_iteration_widening_bn():
    """The §Perf L1 iteration this estimator motivated: at the default
    128x128x128 tiling the fused kernel is *memory-bound* on BNN layer
    shapes (weights re-streamed once per N-panel); widening bn so the
    weight panel stays resident crosses the machine balance point and the
    kernel becomes compute-bound. Recorded in EXPERIMENTS.md §Perf."""
    m, n, k = 169 * 256, 384, 2304  # AlexNet conv4 as im2col
    narrow = default_estimate(1)
    assert not narrow.compute_bound(m, n, k)
    wide = KernelEstimate(bm=128, bn=384, bk=512, dtype_bytes=1)
    assert wide.compute_bound(m, n, k)
    # And the wide tiling still fits VMEM comfortably.
    assert wide.vmem_fraction < 0.1, wide.vmem_fraction


def test_tiny_problems_are_memory_bound():
    est = default_estimate(1)
    assert not est.compute_bound(16, 4, 72)  # the TinyBNN head


def test_arithmetic_intensity_monotone_in_k():
    est = default_estimate(1)
    ai1 = est.arithmetic_intensity(4096, 256, 288)
    ai2 = est.arithmetic_intensity(4096, 256, 2304)
    assert ai2 > ai1


def test_report_renders():
    r = report()
    assert "compute-bound" in r or "memory-bound" in r
    assert "VMEM" in r

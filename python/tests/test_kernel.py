"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, block sizes and data; assertions are exact
(integer kernels — no tolerance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, xnor


def rand_bits(rng, shape):
    return jnp.asarray(rng.integers(0, 2, size=shape), jnp.int32)


def rand_pm1(rng, shape):
    return jnp.asarray(rng.integers(0, 2, size=shape) * 2 - 1, jnp.int32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 96),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_binconv_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand_bits(rng, (m, k))
    w = rand_pm1(rng, (k, n))
    t = jnp.asarray(rng.integers(-2, k + 2, size=(n,)), jnp.int32)
    got = xnor.binconv_matmul(x, w, t)
    want = ref.binconv_ref(x, w, t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 32),
    k=st.integers(1, 64),
    n=st.integers(1, 16),
    bits=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_binsum_matches_ref_integer_inputs(m, k, n, bits, seed):
    """Integer first-layer path: up-to-12-bit activations (§V-A)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2**bits, size=(m, k)), jnp.int32)
    w = rand_pm1(rng, (k, n))
    got = xnor.binsum_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.binsum_ref(x, w)))


@settings(max_examples=15, deadline=None)
@given(
    p=st.integers(1, 64),
    w=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_or_matches_ref(p, w, seed):
    rng = np.random.default_rng(seed)
    x = rand_bits(rng, (p, w))
    got = xnor.maxpool_or(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.maxpool_or_ref(x)))


def test_signed_sum_identity_equals_direct_xnor_popcount():
    """The identity the whole stack rests on: popcount(xnor) computed
    directly equals (signed_sum + fanin) / 2."""
    rng = np.random.default_rng(7)
    x = rand_bits(rng, (13, 29))
    w = rand_pm1(rng, (29, 5))
    direct = ref.xnor_popcount_ref(x, w)
    via_sum = (ref.binsum_ref(2 * x - 1, w) + 29) // 2
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(via_sum))


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 8, 32), (128, 128, 128)])
def test_block_size_invariance(bm, bn, bk):
    """Output must not depend on the tiling (the knob the perf pass turns)."""
    rng = np.random.default_rng(3)
    x = rand_bits(rng, (33, 70))
    w = rand_pm1(rng, (70, 11))
    t = jnp.asarray(rng.integers(0, 70, size=(11,)), jnp.int32)
    got = xnor.binconv_matmul(x, w, t, bm=bm, bn=bn, bk=bk)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.binconv_ref(x, w, t))
    )


def test_degenerate_thresholds():
    """T' <= 0 is always 1; T' > fanin is always 0 (the degenerate cases
    the rust scheduler special-cases too)."""
    rng = np.random.default_rng(5)
    x = rand_bits(rng, (9, 21))
    w = rand_pm1(rng, (21, 4))
    always = xnor.binconv_matmul(x, w, jnp.asarray([-5, 0, 22, 100], jnp.int32))
    got = np.asarray(always)
    assert (got[:, 0] == 1).all() and (got[:, 1] == 1).all()
    assert (got[:, 2] == 0).all() and (got[:, 3] == 0).all()


def test_table2_fanin_288():
    """The Table II workload: 288-input node (3x3 x 32 IFMs)."""
    rng = np.random.default_rng(11)
    x = rand_bits(rng, (4, 288))
    w = rand_pm1(rng, (288, 8))
    t = jnp.asarray(rng.integers(100, 190, size=(8,)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(xnor.binconv_matmul(x, w, t)),
        np.asarray(ref.binconv_ref(x, w, t)),
    )


def test_binsum_saturating_none():
    """Kernel accumulates in int32 — no silent wrap for 12-bit x 2047-deep
    sums (worst case 2^12 * 2048 << 2^31)."""
    x = jnp.full((1, 2048), 4095, jnp.int32)
    w = jnp.ones((2048, 1), jnp.int32)
    out = xnor.binsum_matmul(x, w)
    assert int(out[0, 0]) == 4095 * 2048


def test_jit_cache_stable():
    """Two calls with identical shapes hit the same compiled executable and
    agree (guards against tracing-time randomness)."""
    rng = np.random.default_rng(13)
    x = rand_bits(rng, (8, 24))
    w = rand_pm1(rng, (24, 3))
    t = jnp.asarray([5, 10, 15], jnp.int32)
    a = xnor.binconv_matmul(x, w, t)
    b = xnor.binconv_matmul(x, w, t)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

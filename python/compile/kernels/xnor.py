"""L1 — Pallas kernels for the BNN compute hot-spot.

The paper's TULIP-PE performs XNOR-popcount-threshold with a bit-serial
adder tree; the TPU-idiomatic restatement of the same insight (DESIGN.md
§Hardware-Adaptation) is a *tiled matmul over ±1 operands with the
threshold comparison fused into the epilogue*, so the binarized activation
never round-trips to HBM:

    popcount(xnor(x, w)) >= T'  <=>  (+-1 x) . (+-1 w) >= 2*T' - fanin

Kernels are written with ``BlockSpec`` tiling for VMEM and run under
``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic custom
calls); correctness is pinned against the pure-jnp oracle in ``ref.py`` by
``python/tests/test_kernel.py``. VMEM-footprint / MXU-utilization estimates
for the real-TPU variant are recorded in DESIGN.md §Perf and
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes: one (bm x bk) activation tile + (bk x bn) weight tile +
# the (bm x bn) int32 accumulator block. At the default 128^3 that is
# 3 * 128*128*4 B = 192 KiB << 16 MiB VMEM, leaving ample room for
# double-buffering the HBM->VMEM pipeline (DESIGN.md §Perf).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _block_sizes(m, n, k, bm, bn, bk):
    return min(bm, max(8, m)), min(bn, max(8, n)), min(bk, max(8, k))


def _binconv_kernel(x_ref, w_ref, t_ref, o_ref, *, k_steps: int):
    """Grid (M/bm, N/bn, K/bk). The output block doubles as the int32
    accumulator across K steps; on the last step the threshold comparison
    is fused in-place and the block leaves as {0,1} — the activation never
    exists in memory at integer width (the kernel-level analogue of the
    TULIP-PE's data locality argument)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref[...] + jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.int32)

    @pl.when(k < k_steps - 1)
    def _carry():
        o_ref[...] = acc

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # t_ref carries the signed threshold 2*T' - fanin per column.
        o_ref[...] = (acc >= t_ref[...]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def binconv_matmul(x01, w_pm1, t_popcount, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Binary conv/FC layer as fused XNOR-popcount-threshold.

    Args:
      x01:        (M, K) int32 activations in {0, 1} (im2col windows).
      w_pm1:      (K, N) int32 weights in {-1, +1}.
      t_popcount: (N,) int32 popcount thresholds T' (batch-norm folded).

    Returns:
      (M, N) int32 in {0, 1}: ``popcount(xnor(x, w)) >= T'``.
    """
    m, k = x01.shape
    k2, n = w_pm1.shape
    assert k == k2, (x01.shape, w_pm1.shape)
    fanin = k

    # +-1 encoding. K is zero-padded to the block size: padded positions
    # carry x = 0 in the signed domain and therefore contribute nothing.
    xs = (2 * x01 - 1).astype(jnp.int32)
    ws = w_pm1.astype(jnp.int32)
    t_signed = (2 * t_popcount - fanin).astype(jnp.int32)

    bm, bn, bk = _block_sizes(m, n, k, bm, bn, bk)
    xs = _pad_to(_pad_to(xs, 0, bm), 1, bk)
    ws = _pad_to(_pad_to(ws, 0, bk), 1, bn)
    # Padded output columns compare against an unreachable threshold.
    ts = _pad_to(t_signed.reshape(1, -1), 1, bn)
    mp, kp = xs.shape
    _, np_ = ws.shape
    k_steps = kp // bk

    out = pl.pallas_call(
        functools.partial(_binconv_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xs, ws, ts)
    return out[:m, :n]


def _binsum_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """As `_binconv_kernel` but emits the raw signed sum — the integer
    first-layer path and the classifier head (raw scores)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.int32)
    del k_steps


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def binsum_matmul(x, w_pm1, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Signed weighted sum with binary weights: integer activations (first
    layers, §V-B) or +-1-encoded activations (classifier scores).

    Args:
      x:     (M, K) int32 activations.
      w_pm1: (K, N) int32 weights in {-1, +1}.

    Returns:
      (M, N) int32 signed sums.
    """
    m, k = x.shape
    k2, n = w_pm1.shape
    assert k == k2
    xs = x.astype(jnp.int32)
    ws = w_pm1.astype(jnp.int32)
    bm, bn, bk = _block_sizes(m, n, k, bm, bn, bk)
    xs = _pad_to(_pad_to(xs, 0, bm), 1, bk)
    ws = _pad_to(_pad_to(ws, 0, bk), 1, bn)
    mp, kp = xs.shape
    _, np_ = ws.shape
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_binsum_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xs, ws)
    return out[:m, :n]


def _maxpool_kernel(x_ref, o_ref):
    """OR-maxpool (§IV-D): max over the window axis of {0,1} inputs."""
    o_ref[...] = jnp.max(x_ref[...], axis=1)


@jax.jit
def maxpool_or(windows01):
    """Max-pooling as OR over pooling windows.

    Args:
      windows01: (P, W) int32 in {0,1} — P pooled positions x W window bits.

    Returns:
      (P,) int32 in {0,1}.
    """
    p, w = windows01.shape
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((p, w), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((p,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.int32),
        interpret=True,
    )(windows01.astype(jnp.int32))

"""L1 §Perf instrumentation: VMEM footprint and MXU-utilization *estimates*
for the Pallas kernels' real-TPU variant.

``interpret=True`` gives CPU-numpy wallclock, which is NOT a TPU proxy —
the optimization target for the kernel is structural (DESIGN.md §Perf).
This module makes those structural numbers executable: the EXPERIMENTS.md
§Perf L1 figures are produced by these functions and pinned by
``python/tests/test_vmem.py``.
"""

from dataclasses import dataclass

# TPU-generation reference constants (v4-class core, the documented target
# of the BlockSpec sizing; see DESIGN.md §Hardware-Adaptation).
VMEM_BYTES = 16 * 1024 * 1024
MXU_LANES = 128
HBM_BW_BYTES_PER_S = 1.2e12
MXU_INT8_OPS_PER_S = 2 * 275e12  # 2 ops/MAC at the bf16/int8 rate


@dataclass
class KernelEstimate:
    """Structural estimate for one (bm, bn, bk) tiling of the fused
    XNOR-popcount-threshold matmul."""

    bm: int
    bn: int
    bk: int
    dtype_bytes: int

    @property
    def tile_bytes(self) -> int:
        """Resident tiles: activation (bm x bk) + weight (bk x bn) +
        accumulator/output (bm x bn)."""
        return self.dtype_bytes * (self.bm * self.bk + self.bk * self.bn + self.bm * self.bn)

    @property
    def vmem_fraction(self) -> float:
        """Fraction of VMEM one pipeline stage occupies (x2 for double
        buffering of the input tiles)."""
        double_buffered = self.tile_bytes + self.dtype_bytes * (
            self.bm * self.bk + self.bk * self.bn
        )
        return double_buffered / VMEM_BYTES

    def weights_resident(self, n: int, k: int) -> bool:
        """Can the full K x N weight panel stay pinned in VMEM across the
        M sweep? Requires bn to cover N (otherwise the (i, j, kk) grid
        re-streams weight blocks per M panel) and the panel to fit in half
        of VMEM (the other half double-buffers activations)."""
        return self.bn >= n and k * n * self.dtype_bytes <= VMEM_BYTES // 2

    def arithmetic_intensity(self, m: int, n: int, k: int) -> float:
        """Ops per HBM byte for the whole problem under this tiling:
        2·M·N·K ops; HBM traffic = activations once per N-panel sweep +
        weights (once if VMEM-resident, else once per M-panel sweep) +
        outputs once."""
        ops = 2.0 * m * n * k
        n_panels = max(1, -(-n // self.bn))
        m_panels = max(1, -(-m // self.bm))
        w_sweeps = 1 if self.weights_resident(n, k) else m_panels
        bytes_moved = self.dtype_bytes * (
            m * k * n_panels + k * n * w_sweeps + m * n
        )
        return ops / bytes_moved

    def compute_bound(self, m: int, n: int, k: int) -> bool:
        """Roofline: compute-bound iff arithmetic intensity exceeds the
        machine balance point."""
        balance = MXU_INT8_OPS_PER_S / HBM_BW_BYTES_PER_S
        return self.arithmetic_intensity(m, n, k) >= balance

    def mxu_utilization(self) -> float:
        """Lane-occupancy estimate: fraction of the 128x128 systolic tile
        the block shapes keep busy."""
        return min(1.0, self.bm / MXU_LANES) * min(1.0, self.bn / MXU_LANES)


def default_estimate(dtype_bytes: int = 4) -> KernelEstimate:
    """The shipped 128x128x128 int32 tiling (interpret mode). The real-TPU
    variant would use int8 (+-1 operands), dtype_bytes = 1."""
    return KernelEstimate(bm=128, bn=128, bk=128, dtype_bytes=dtype_bytes)


def report() -> str:
    """Human-readable §Perf block (printed by `python -m compile.kernels.vmem`)."""
    lines = []
    for name, est in [
        ("interpret/int32", default_estimate(4)),
        ("real-TPU/int8", default_estimate(1)),
    ]:
        m, n, k = 169 * 256, 384, 2304  # AlexNet conv4 as im2col
        lines.append(
            f"{name}: tiles {est.tile_bytes / 1024:.0f} KiB "
            f"({est.vmem_fraction * 100:.1f}% of VMEM double-buffered), "
            f"MXU occupancy {est.mxu_utilization() * 100:.0f}%, "
            f"AI {est.arithmetic_intensity(m, n, k):.0f} op/B "
            f"({'compute' if est.compute_bound(m, n, k) else 'memory'}-bound)"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())

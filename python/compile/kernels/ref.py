"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in ``xnor.py`` must agree with these reference functions
exactly (integer arithmetic, no tolerance) over the shape/dtype sweeps in
``python/tests/test_kernel.py``.
"""

import jax.numpy as jnp


def binconv_ref(x01, w_pm1, t_popcount):
    """popcount(xnor(x, w)) >= T' via the signed-sum identity."""
    fanin = x01.shape[1]
    xs = (2 * x01 - 1).astype(jnp.int32)
    s = xs @ w_pm1.astype(jnp.int32)
    popcount = (s + fanin) // 2
    return (popcount >= t_popcount.astype(jnp.int32)).astype(jnp.int32)


def binsum_ref(x, w_pm1):
    """Raw signed weighted sum."""
    return x.astype(jnp.int32) @ w_pm1.astype(jnp.int32)


def maxpool_or_ref(windows01):
    """OR over the window axis."""
    return jnp.max(windows01.astype(jnp.int32), axis=1)


def xnor_popcount_ref(x01, w_pm1):
    """Direct popcount-of-XNOR definition (cross-validates the signed-sum
    identity itself)."""
    w01 = (w_pm1 > 0).astype(jnp.int32)
    # xnor(a, b) over {0,1}: 1 - (a ^ b) = a*b + (1-a)*(1-b)
    agree = x01[:, :, None] * w01[None, :, :] + (1 - x01[:, :, None]) * (1 - w01[None, :, :])
    return agree.sum(axis=1)

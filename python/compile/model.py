"""L2 — the JAX golden model of a BNN, built on the L1 Pallas kernels.

This is the bit-exact functional specification of what the TULIP simulator
computes: XNOR-popcount-threshold convolutions (zero padding, (ky, kx, c)
window order — the same product ordering the rust scheduler streams into
the PE adder trees), OR-maxpooling, and a popcount-score classifier head.

Layout conventions (must match ``rust/src/bnn``):
  * activations: (H, W, C) int32 in {0, 1};
  * weights:     (z2, fanin) int32 in {-1, +1}, fanin ordered (ky, kx, c);
  * thresholds:  (z2,) int32 popcount thresholds (batch-norm folded, §IV-D).

The model is lowered once to HLO text by ``aot.py`` and served from rust
via PJRT; python never runs on the request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels import xnor


def im2col(x_hwc, k, stride=1, pad=1):
    """Extract zero-padded k x k windows in (ky, kx, c) order.

    Returns (out_h * out_w, k * k * C) int32.
    """
    h, w, c = x_hwc.shape
    xp = jnp.pad(x_hwc, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            patch = jax.lax.slice(
                xp, (ky, kx, 0), (ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            cols.append(patch.reshape(oh * ow, c))
    return jnp.concatenate(cols, axis=1)


def conv_bin_layer(x_hwc, w_zf, t, k=3, stride=1, pad=1):
    """Binary conv layer: XNOR-popcount-threshold via the Pallas kernel."""
    h, w, _ = x_hwc.shape
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = im2col(x_hwc, k, stride, pad)  # (oh*ow, fanin)
    out = xnor.binconv_matmul(cols, w_zf.T, t)  # (oh*ow, z2)
    return out.reshape(oh, ow, w_zf.shape[0])


def maxpool_layer(x_hwc, k=2, stride=2):
    """OR-maxpool via the Pallas kernel; windows per (position, channel)."""
    h, w, c = x_hwc.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    wins = []
    for ky in range(k):
        for kx in range(k):
            patch = jax.lax.slice(
                x_hwc, (ky, kx, 0), (ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1, c),
                (stride, stride, 1),
            )
            wins.append(patch.reshape(oh * ow * c))
    windows = jnp.stack(wins, axis=1)  # (oh*ow*c, k*k)
    return xnor.maxpool_or(windows).reshape(oh, ow, c)


def fc_scores(x_flat01, w_zf):
    """Classifier head: raw popcount scores (matches rust `fc_scores`)."""
    fanin = x_flat01.shape[0]
    xs = (2 * x_flat01 - 1).astype(jnp.int32).reshape(1, fanin)
    s = xnor.binsum_matmul(xs, w_zf.T)  # (1, classes) signed
    return ((s[0] + fanin) // 2).astype(jnp.int32)


def fc_bin(x_flat01, w_zf, t):
    """Hidden binary FC layer: thresholded popcount."""
    cols = x_flat01.reshape(1, -1)
    return xnor.binconv_matmul(cols, w_zf.T, t)[0]


def tiny_bnn_forward(x, w1, t1, w2, t2, w3):
    """The TinyBNN of ``rust/src/bnn/zoo.rs::tiny_bnn(size, ch, classes)``:

        conv(3x3, ch -> ch) + pool2 -> conv(3x3, ch -> 2ch) + pool2
        -> fc(flat -> classes) popcount scores.

    All shapes static; returns (classes,) int32 scores.
    """
    a = conv_bin_layer(x, w1, t1)
    a = maxpool_layer(a)
    a = conv_bin_layer(a, w2, t2)
    a = maxpool_layer(a)
    return fc_scores(a.reshape(-1), w3)


def tiny_bnn_specs(size=16, ch=8, classes=4):
    """ShapeDtypeStructs for AOT lowering of `tiny_bnn_forward`."""
    i32 = jnp.int32
    fan1 = 9 * ch
    fan2 = 9 * ch
    flat = (size // 4) * (size // 4) * (2 * ch)
    return (
        jax.ShapeDtypeStruct((size, size, ch), i32),
        jax.ShapeDtypeStruct((ch, fan1), i32),
        jax.ShapeDtypeStruct((ch,), i32),
        jax.ShapeDtypeStruct((2 * ch, fan2), i32),
        jax.ShapeDtypeStruct((2 * ch,), i32),
        jax.ShapeDtypeStruct((classes, flat), i32),
    )


def binconv_layer_entry(x, w, t):
    """Single-conv-layer golden (16x16x8 -> 8 channels), for layer-level
    cross-checks against the bit-true simulator."""
    return conv_bin_layer(x, w, t)


def binconv_layer_specs(size=16, ch=8, z2=8):
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((size, size, ch), i32),
        jax.ShapeDtypeStruct((z2, 9 * ch), i32),
        jax.ShapeDtypeStruct((z2,), i32),
    )


def fc_head_entry(x_flat, w):
    """Classifier-head golden (256 -> 4 popcount scores)."""
    return fc_scores(x_flat, w)


def fc_head_specs(flat=256, classes=4):
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((flat,), i32),
        jax.ShapeDtypeStruct((classes, flat), i32),
    )

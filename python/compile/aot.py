"""AOT compile path: lower the L2 golden models to **HLO text** artifacts
that the rust runtime loads via PJRT (`rust/src/runtime`).

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Artifact registry: stem -> (entry function, example-arg specs).
ARTIFACTS = {
    "tiny_bnn": (model.tiny_bnn_forward, model.tiny_bnn_specs()),
    "binconv_layer": (model.binconv_layer_entry, model.binconv_layer_specs()),
    "fc_head": (model.fc_head_entry, model.fc_head_specs()),
}


def emit(stem: str, out_dir: str) -> str:
    fn, specs = ARTIFACTS[stem]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{stem}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", choices=sorted(ARTIFACTS), default=None)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    stems = [args.only] if args.only else sorted(ARTIFACTS)
    for stem in stems:
        path = emit(stem, args.out_dir)
        size = os.path.getsize(path)
        print(f"wrote {path} ({size} bytes)")


if __name__ == "__main__":
    main()
